"""The `Experiment` driver: memoized ``run()`` / ``sweep()`` over the grid.

One call path evaluates any (registered workload × system × buffer config)
under any registered backend.  Work that is invariant across sweep points
is computed once and reused:

* **graphs** — one build per workload (the legacy path rebuilt the graph
  on every ``evaluate()`` call, including once per normalisation baseline),
* **fusion plans and group tilings** — one per (workload, tile grid);
  tilings are buffer-independent, so a (GBUF, LBUF) sweep never re-tiles,
* **mapped traces** — one per (workload, system, gbuf, lbuf); the
  normalisation baseline is one of these, shared by every point,
* **lowered burst traces** — one per (trace, arch), shared across issue
  policies (the lowering dominates burst-sim cost on big traces),
* **results** — one backend evaluation per resolved spec.

``Experiment.stats`` counts builds vs cache hits; tests assert on it.
A process-wide :func:`default_experiment` backs the legacy
``repro.pim.ppa`` shims so old and new entry points share one cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import sys as _sys
import time
from pathlib import Path
from typing import Any, Iterable, Sequence

from repro.core import dataflow
from repro.core.commands import Trace
from repro.core.fusion import (FusionPlan, PlanSig, plan_from_signature,
                               plan_fused)
from repro.core.graph import Graph
from repro.experiment import systems as _systems  # registers built-ins
from repro.experiment import workloads as _workloads  # registers built-ins
from repro.experiment.backends import (BACKENDS, EvalResult, EvalSpec,
                                       resolve_engine)
from repro.experiment.registry import (SYSTEMS, WORKLOADS, Registry,
                                       SystemSpec, WorkloadSpec)
from repro.faults.spec import FaultSpec
from repro.obs.counters import CounterRegistry
from repro.obs.profile import active_profiler, profiled, span
from repro.pim.arch import PIMArch

BASELINE_SYSTEM = _systems.BASELINE_SYSTEM

_ = _workloads  # imported for registration side effects


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One design point of a :meth:`Experiment.pareto_frontier` sweep,
    tagged with whether another grid point Pareto-dominates it on the
    (cycles, energy, area) triple."""

    result: EvalResult
    dominated: bool


@dataclasses.dataclass(frozen=True)
class SweepFailure:
    """One grid point a resilient sweep gave up on after its retry
    budget: ``code`` is ``"crash"`` (a worker death broke the pool),
    ``"timeout"`` (the chunk blew its wall-clock deadline) or ``"error"``
    (the chunk raised).  Quarantined points are served as coded failure
    rows (see :meth:`Experiment._failure_result`) instead of aborting the
    sweep, recorded on :attr:`Experiment.failures` and — when a
    checkpoint journal is attached — in the journal."""

    spec: EvalSpec
    code: str
    message: str
    attempts: int


def _dominates(a: tuple[float, ...], b: tuple[float, ...]) -> bool:
    """True if ``a`` is no worse than ``b`` everywhere and strictly better
    somewhere (ties dominate nothing — duplicate points both survive)."""
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


def pareto_tags(results: Sequence[EvalResult]) -> list[bool]:
    """Per-result dominated flags over (cycles, energy_nj, area_mm2) —
    smaller is better on every axis."""
    metrics = [(r.cycles, r.energy_nj, r.area_mm2) for r in results]
    return [any(_dominates(other, mine)
                for j, other in enumerate(metrics) if j != i)
            for i, mine in enumerate(metrics)]


def _sweep_worker(job: dict[str, Any]) -> dict[str, Any]:
    """Process-pool entry point for :meth:`Experiment.sweep`: evaluate one
    chunk of grid points on a fresh Experiment (over the module-level
    registries, with the parent's pinned plan overrides re-applied from
    the shipped :func:`repro.plan.artifacts.override_records`) and ship
    the results, build stats, folded collector and per-point progress
    back for the parent to merge.  The worker's Experiment reads the
    on-disk cache from the environment, so spawn pools stop re-lowering
    the same trace once any process has stored it.  When ``REPRO_CHAOS``
    is set, the chaos harness (:mod:`repro.faults.chaos`) gets a shot at
    every point first — crash/hang injection for the resilience tests and
    the CI chaos step; production sweeps never pay the check."""
    exp = Experiment()
    if job.get("overrides"):
        from repro.plan.artifacts import apply_override_records
        apply_override_records(exp.systems, job["overrides"])
    collector = job.get("collector")
    if collector is not None:
        exp.collector = collector
    chaos = os.environ.get("REPRO_CHAOS")
    results: list[EvalResult] = []
    progress: list[tuple[EvalSpec, float]] = []
    for spec in job["specs"]:
        if chaos:
            from repro.faults.chaos import maybe_chaos
            maybe_chaos(exp.resolve(spec))
        t0 = time.perf_counter()
        results.append(exp.run(spec))
        progress.append((spec, time.perf_counter() - t0))
    return {"results": results, "stats": dict(exp.stats),
            "collector": collector, "progress": progress}


class Experiment:
    """Declarative, memoizing evaluation driver over the registries."""

    def __init__(self,
                 workloads: Registry[WorkloadSpec] = WORKLOADS,
                 systems: Registry[SystemSpec] = SYSTEMS,
                 backends: Registry = BACKENDS,
                 baseline_system: str = BASELINE_SYSTEM,
                 disk_cache: Any = "env") -> None:
        self.workloads = workloads
        self.systems = systems
        self.backends = backends
        self.baseline_system = baseline_system
        # on-disk cache for columnar lowerings / batch orders: the default
        # sentinel reads $REPRO_CACHE_DIR / $REPRO_CACHE (off unless opted
        # in); pass a DiskCache to force one, or None to disable
        if disk_cache == "env":
            from repro.experiment.cache import DiskCache
            disk_cache = DiskCache.from_env()
        self.disk_cache = disk_cache
        # a CounterRegistry IS a MutableMapping, so dict-style call sites
        # (tests assert stats["trace_hits"], dict(exp.stats)) keep working
        # while gaining the namespaced snapshot/JSON API of repro.obs
        self.stats: CounterRegistry = CounterRegistry({
            "graph_builds": 0, "plan_builds": 0, "plan_searches": 0,
            "tiling_builds": 0,
            "trace_maps": 0, "trace_hits": 0, "lowerings": 0,
            "columnar_lowerings": 0, "batchings": 0,
            "cycle_models": 0, "energy_models": 0,
            "backend_evals": 0, "result_hits": 0,
            "disk_hits": 0, "disk_misses": 0, "disk_stores": 0,
            "disk_corrupt": 0,
            "parallel_chunks": 0, "parallel_points": 0,
            "remaps": 0,
            "sweep_retries": 0, "sweep_timeouts": 0,
            "sweep_quarantined": 0, "journal_restored": 0,
        })
        # optional repro.obs.trace.TraceCollector: when set, the burst-sim
        # backend streams replay events into it (EvalContext hook).  NOTE:
        # memoized results do not re-replay — attach the collector before
        # the point of interest is first evaluated (or use a fresh
        # Experiment, as benchmarks/bottleneck_report.py does).  A
        # FoldingCollector (fork()/merge()) also rides sweep(workers=N)
        # pools; any other collector keeps those sweeps serial.
        self.collector: Any = None
        self._graphs: dict[str, Graph] = {}
        self._plans: dict[tuple, FusionPlan] = {}
        self._searches: dict[tuple[str, str, int, int], Any] = {}
        self._tilings: dict[tuple[str, PlanSig], dict] = {}
        self._traces: dict[tuple, Trace] = {}
        # identity-keyed per-(trace, arch[, extra]) derivations (lowered
        # bursts keyed by row-reuse mode, analytic cycle/energy reports):
        # {key: (trace_ref, value)} — the stored strong ref both keeps the
        # id() stable and lets the lookup verify it still names the same
        # trace object
        self._lowered: dict[tuple, tuple[Trace, Any]] = {}
        self._columnar: dict[tuple, tuple[Trace, Any]] = {}
        self._batched: dict[tuple, tuple[Trace, Any]] = {}
        self._cycle_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._energy_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._degraded: dict[tuple, tuple[Trace, Any]] = {}
        self._results: dict[EvalSpec, EvalResult] = {}
        # sweep-resilience state: poison points quarantined after their
        # retry budget (served as coded failure rows, never re-run in the
        # parent) plus every quarantine decision in arrival order
        self._quarantined: dict[EvalSpec, SweepFailure] = {}
        self._failed: list[SweepFailure] = []

    # ------------------------------------------------------------------
    # memoized build pipeline
    # ------------------------------------------------------------------

    def graph(self, workload: str) -> Graph:
        """The workload's graph, built once per Experiment (treat as
        read-only — every trace and result shares it)."""
        g = self._graphs.get(workload)
        if g is None:
            g = self.workloads.get(workload).build()
            self.stats["graph_builds"] += 1
            self._graphs[workload] = g
        return g

    def plan(self, workload: str, tile_grid: tuple[int, int],
             system: str | None = None, source: str = "default",
             gbuf_bytes: int | None = None,
             lbuf_bytes: int | None = None) -> FusionPlan:
        """The fusion plan for a workload on a tile grid.

        ``source`` selects how the partition is decided (the
        ``EvalSpec.plan`` knob): ``"greedy"`` is the paper's rule;
        ``"default"`` additionally honors the system's pinned per-workload
        override (:attr:`SystemSpec.plan_overrides`) when ``system`` is
        given; ``"searched"`` is the DP optimum of
        :meth:`search_plan` at the (resolved) buffer point — the only
        source whose plan depends on buffer sizes.
        """
        if source == "searched":
            if system is None:
                raise ValueError("plan source 'searched' needs the system "
                                 "(the search costs its arch)")
            return self.search_plan(workload, system, gbuf_bytes,
                                    lbuf_bytes).plan
        if source not in ("default", "greedy"):
            raise ValueError(f"unknown plan source {source!r}; choose from "
                             "['default', 'greedy', 'searched']")
        if source == "default" and system is not None:
            sig = self.systems.get(system).plan_override(workload)
            if sig is not None:
                # keyed by the SIGNATURE, not the system: re-registering a
                # spec with a different override can never serve a stale
                # plan, and systems sharing an override share the build
                key = ("override", workload, sig)
                p = self._plans.get(key)
                if p is None:
                    p = plan_from_signature(self.graph(workload), sig)
                    self.stats["plan_builds"] += 1
                    self._plans[key] = p
                return p
        key = ("greedy", workload, *tile_grid)
        p = self._plans.get(key)
        if p is None:
            p = plan_fused(self.graph(workload), *tile_grid)
            self.stats["plan_builds"] += 1
            self._plans[key] = p
        return p

    def search_plan(self, workload: str, system: str,
                    gbuf_bytes: int | None = None,
                    lbuf_bytes: int | None = None,
                    trace_cost=None) -> Any:
        """DP-search the fusion partition for (workload, system) at a
        buffer point (defaults: the system's design point) — returns the
        :class:`repro.plan.dp.SearchResult` (memoized per resolved point;
        custom ``trace_cost`` callables bypass the memo)."""
        spec = self.systems.get(system)
        if spec.tile_grid is None:
            raise ValueError(f"system {system!r} runs the layer-by-layer "
                             "dataflow; there is no partition to search")
        g0, l0 = spec.default_buffers
        gbuf = g0 if gbuf_bytes is None else gbuf_bytes
        lbuf = l0 if lbuf_bytes is None else lbuf_bytes
        key = (workload, system, gbuf, lbuf)
        if trace_cost is None:
            hit = self._searches.get(key)
            if hit is not None:
                return hit
        from repro.plan.dp import search_partition
        with span("plan.search", workload=workload, system=system):
            sr = search_partition(self.graph(workload),
                                  spec.make_arch(gbuf, lbuf),
                                  *spec.tile_grid, trace_cost=trace_cost)
        self.stats["plan_searches"] += 1
        if trace_cost is None:
            self._searches[key] = sr
        return sr

    def pin_plan(self, workload: str, system: str,
                 plan: FusionPlan | None = None) -> "SystemSpec":
        """Pin a fusion plan as the system's per-workload override, so
        ``plan="default"`` specs reproduce it from now on.  ``plan=None``
        searches first (:meth:`search_plan` at the system's design point).
        Re-registers the system spec (in THIS experiment's registry — pass
        ``SYSTEMS.clone()`` to the constructor to keep the process-wide
        registry untouched) and drops the caches the override invalidates.
        """
        spec = self.systems.get(system)
        if plan is None:
            plan = self.search_plan(workload, system).plan
        graph = self.graph(workload)
        if plan.graph.name != graph.name or len(plan.graph) != len(graph):
            raise ValueError(
                f"plan was built for graph {plan.graph.name!r} "
                f"({len(plan.graph)} layers), not workload {workload!r} "
                f"({graph.name!r}, {len(graph)} layers)")
        new_spec = spec.with_plan_override(workload, plan.signature())
        self.systems.register(system, new_spec, replace=True)
        self._traces = {k: v for k, v in self._traces.items()
                        if not (k[0] == workload and k[1] == system)}
        self._results = {s: r for s, r in self._results.items()
                         if not (s.workload == workload
                                 and s.system == system
                                 and s.plan == "default")}
        return new_spec

    def tilings(self, workload: str, tile_grid: tuple[int, int],
                plan: FusionPlan | None = None) -> dict:
        """Buffer-independent tiling solutions for every fused group —
        the expensive geometry a (GBUF, LBUF) sweep must never redo.
        Keyed by the plan's signature, so every plan source (greedy,
        override, searched) shares tilings for identical partitions."""
        if plan is None:
            plan = self.plan(workload, tile_grid)
        key = (workload, plan.signature())
        t = self._tilings.get(key)
        if t is None:
            t = dataflow.plan_tilings(plan)
            self.stats["tiling_builds"] += 1
            self._tilings[key] = t
        return t

    def trace(self, workload: str, system: str, gbuf_bytes: int,
              lbuf_bytes: int, plan: str = "default") -> Trace:
        """The mapped command trace for one fully-resolved grid point.
        Keyed by the RESOLVED plan signature, so plan sources that agree
        on the partition share one trace."""
        spec = self.systems.get(system)
        fused_plan: FusionPlan | None = None
        if spec.tile_grid is None:
            plan_key = None
        else:
            fused_plan = self.plan(workload, spec.tile_grid, system=system,
                                   source=plan, gbuf_bytes=gbuf_bytes,
                                   lbuf_bytes=lbuf_bytes)
            plan_key = fused_plan.signature()
        key = (workload, system, gbuf_bytes, lbuf_bytes, plan_key)
        tr = self._traces.get(key)
        if tr is not None:
            self.stats["trace_hits"] += 1
            return tr
        arch = spec.make_arch(gbuf_bytes, lbuf_bytes)
        with span("experiment.map", workload=workload, system=system):
            if fused_plan is None:
                tr = dataflow.map_baseline(self.graph(workload), arch)
            else:
                tr = dataflow.map_pimfused(
                    fused_plan, arch,
                    tilings=self.tilings(workload, spec.tile_grid,
                                         plan=fused_plan))
        self.stats["trace_maps"] += 1
        self._traces[key] = tr
        return tr

    def _per_trace(self, cache: dict, trace: Trace, arch: PIMArch,
                   build, stat: str, extra: Any = None,
                   load=None, store=None) -> Any:
        """``load``/``store`` are the optional on-disk hooks wired by
        :meth:`_disk_sync`: on an in-memory miss, ``load()`` is tried
        first (a non-``None`` return is a disk hit), otherwise ``build()``
        runs and ``store(value)`` persists it."""
        key = (id(trace), arch.name, arch.gbuf_bytes, arch.lbuf_bytes, extra)
        hit = cache.get(key)
        if hit is not None and hit[0] is trace:
            return hit[1]
        value = None
        if load is not None:
            value = load()
            self.stats["disk_hits" if value is not None
                       else "disk_misses"] += 1
        if value is None:
            # one span per derivation family: experiment.lowerings,
            # experiment.batchings, experiment.cycle_models, ...
            with span(f"experiment.{stat}"):
                value = build()
            self.stats[stat] += 1
            if store is not None:
                store(value)
                self.stats["disk_stores"] += 1
        cache[key] = (trace, value)
        return value

    def lowered(self, trace: Trace, arch: PIMArch,
                row_reuse: bool = True) -> Any:
        """Burst-lowered trace, shared across issue policies and keyed by
        row-reuse mode (:class:`repro.experiment.backends.EvalContext`
        hook)."""
        from repro.sim.burst import lower_trace
        return self._per_trace(self._lowered, trace, arch,
                               lambda: lower_trace(trace, arch,
                                                   row_reuse=row_reuse),
                               "lowerings", extra=row_reuse)

    def columnar(self, trace: Trace, arch: PIMArch,
                 row_reuse: bool = True, load=None, store=None) -> Any:
        """Columnar (structure-of-arrays) burst lowering for the fast-path
        engine — cached like :meth:`lowered`, and built directly from the
        trace (vectorized emission, no intermediate ``BurstOp`` objects).
        ``load``/``store`` are :meth:`_disk_sync`'s on-disk hooks."""
        from repro.sim.burst import lower_trace_columnar
        return self._per_trace(self._columnar, trace, arch,
                               lambda: lower_trace_columnar(
                                   trace, arch, row_reuse=row_reuse),
                               "columnar_lowerings", extra=row_reuse,
                               load=load, store=store)

    def batched(self, trace: Trace, arch: PIMArch, row_reuse: bool,
                policy: str, engine: str, load=None, store=None) -> Any:
        """Batched burst ordering for a batching policy (``row-aware``),
        cached per (lowering, policy) so a multi-policy sweep sorts each
        command's bursts once instead of once per ``simulate()`` call.
        ``load``/``store`` are :meth:`_disk_sync`'s on-disk hooks."""
        def build():
            if engine == "columnar":
                from repro.sim.scheduler import batch_same_row_columnar
                return batch_same_row_columnar(
                    self.columnar(trace, arch, row_reuse), policy)
            from repro.sim.scheduler import batch_same_row
            return [batch_same_row(ops)
                    for ops in self.lowered(trace, arch, row_reuse)]
        return self._per_trace(self._batched, trace, arch, build,
                               "batchings",
                               extra=(row_reuse, policy, engine),
                               load=load, store=store)

    def degraded(self, trace: Trace, arch: PIMArch,
                 faults: FaultSpec) -> Trace:
        """Degraded-mode trace for a STRUCTURAL fault scenario
        (:func:`repro.faults.remap.remap_trace`), memoized per
        (trace, arch, faults) — a degraded trace is shared across issue
        policies and engines like any other per-trace derivation
        (:class:`~repro.experiment.backends.EvalContext` hook)."""
        from repro.faults.remap import remap_trace
        return self._per_trace(self._degraded, trace, arch,
                               lambda: remap_trace(trace, arch, faults),
                               "remaps", extra=faults)

    def cycle_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic cycle report, policy-independent — computed once per
        (trace, arch) however many backends/policies consume it."""
        from repro.pim.timing import simulate_cycles
        return self._per_trace(self._cycle_reports, trace, arch,
                               lambda: simulate_cycles(trace, arch),
                               "cycle_models")

    def energy_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic energy report, policy-independent (as above)."""
        from repro.pim.energy import simulate_energy
        return self._per_trace(self._energy_reports, trace, arch,
                               lambda: simulate_energy(trace, arch),
                               "energy_models")

    def counters(self) -> CounterRegistry:
        """Point-in-time :class:`~repro.obs.counters.CounterRegistry` with
        the experiment's cache stats under the ``experiment.*`` namespace
        (a copy — mutate :attr:`stats` for live counting).  Callers merge
        per-replay counters in via
        :func:`repro.obs.counters.counters_from_sim_result`."""
        reg = CounterRegistry()
        reg.merge(self.stats, prefix="experiment")
        if self.disk_cache is not None:
            reg.merge(self.disk_cache.stats,
                      prefix="experiment.disk_cache")
        return reg

    @property
    def failures(self) -> list[SweepFailure]:
        """Every grid point resilient sweeps quarantined (gave up on
        after the retry budget), in arrival order."""
        return list(self._failed)

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------

    def resolve(self, spec: EvalSpec) -> EvalSpec:
        """Fill unset buffer sizes from the system's default design point."""
        sys_spec = self.systems.get(spec.system)
        g0, l0 = sys_spec.default_buffers
        return dataclasses.replace(
            spec,
            gbuf_bytes=g0 if spec.gbuf_bytes is None else spec.gbuf_bytes,
            lbuf_bytes=l0 if spec.lbuf_bytes is None else spec.lbuf_bytes)

    def _disk_sync(self, spec: EvalSpec, trace: Trace,
                   arch: PIMArch) -> None:
        """Prime the in-memory columnar/batched memos from the on-disk
        cache (or persist fresh builds into it) for one resolved burst-sim
        grid point — the one place workload / system / resolved plan
        signature are all known, so the content-addressed key can be
        formed.  The backend's later ``ctx.columnar`` / ``ctx.batched``
        calls then hit the primed memo."""
        dc = self.disk_cache
        corrupt0 = dc.stats.get("corrupt", 0)
        try:
            self._disk_sync_inner(spec, trace, arch, dc)
        finally:
            # surface the cache's corruption-quarantine count on the
            # Experiment so callers need not reach into DiskCache.stats
            self.stats["disk_corrupt"] += \
                dc.stats.get("corrupt", 0) - corrupt0

    def _disk_sync_inner(self, spec: EvalSpec, trace: Trace,
                         arch: PIMArch, dc: Any) -> None:
        from repro.experiment.cache import LOWERING_VERSION, arch_fingerprint
        from repro.sim.scheduler import BATCHING_POLICIES, seed_batched
        sys_spec = self.systems.get(spec.system)
        plan_sig: Any = None
        if sys_spec.tile_grid is not None:
            plan_sig = self.plan(spec.workload, sys_spec.tile_grid,
                                 system=spec.system, source=spec.plan,
                                 gbuf_bytes=spec.gbuf_bytes,
                                 lbuf_bytes=spec.lbuf_bytes).signature()
        base_key = dc.key_for(
            kind="columnar", version=LOWERING_VERSION,
            workload=spec.workload, system=spec.system,
            plan=plan_sig, row_reuse=spec.row_reuse,
            arch=arch_fingerprint(arch))
        cols = self.columnar(
            trace, arch, spec.row_reuse,
            load=lambda: dc.load_columnar(base_key, trace, arch),
            store=lambda c: dc.store_columnar(base_key, c))
        if spec.policy not in BATCHING_POLICIES:
            return
        order_key = dc.key_for(kind="batch-order", base=base_key,
                               policy=spec.policy)

        def load() -> Any:
            order = dc.load_order(order_key, cols)
            if order is None:
                return None
            return seed_batched(cols, spec.policy, order)

        self.batched(trace, arch, spec.row_reuse, spec.policy, "columnar",
                     load=load,
                     store=lambda b: dc.store_order(order_key,
                                                    b.batch_order))

    def run(self, spec: EvalSpec | None = None, **kwargs) -> EvalResult:
        """Evaluate one grid point (``EvalSpec`` or its fields as kwargs)."""
        if spec is None:
            spec = EvalSpec(**kwargs)
        elif kwargs:
            spec = dataclasses.replace(spec, **kwargs)
        spec = self.resolve(spec)
        cached = self._results.get(spec)
        if cached is not None:
            self.stats["result_hits"] += 1
            return cached
        backend = self.backends.get(spec.backend)
        sys_spec = self.systems.get(spec.system)
        arch = sys_spec.make_arch(spec.gbuf_bytes, spec.lbuf_bytes)
        trace = self.trace(spec.workload, spec.system, spec.gbuf_bytes,
                           spec.lbuf_bytes, plan=spec.plan)
        if (self.disk_cache is not None and spec.backend == "burst-sim"
                and resolve_engine(spec.engine) == "columnar"
                # the disk key addresses the HEALTHY lowering; a
                # structurally degraded point lowers its remapped trace
                # in-memory instead of priming (or polluting) the cache
                and (spec.faults is None
                     or not spec.faults.has_structural)):
            self._disk_sync(spec, trace, arch)
        with span("experiment.evaluate", workload=spec.workload,
                  system=spec.system, backend=spec.backend):
            result = backend.evaluate(trace, arch, spec, ctx=self)
        self.stats["backend_evals"] += 1
        self._results[spec] = result
        return result

    def baseline(self, workload: str, backend: str = "analytic",
                 policy: str = "serial",
                 row_reuse: bool = True,
                 engine: str = "columnar") -> EvalResult:
        """The paper's 1.0: the baseline system at its own design point,
        evaluated under the SAME backend/policy/row-reuse/engine mode as
        the results it scales."""
        return self.run(EvalSpec(workload=workload,
                                 system=self.baseline_system,
                                 backend=backend, policy=policy,
                                 row_reuse=row_reuse, engine=engine))

    def normalized(self, result: EvalResult) -> dict[str, float]:
        """Normalize one result to its workload's baseline (memoized — the
        baseline is evaluated once per workload, not once per point)."""
        return result.normalized(self.baseline(result.workload,
                                               backend=result.spec.backend,
                                               policy=result.spec.policy,
                                               row_reuse=result.spec.row_reuse,
                                               engine=result.spec.engine))

    # ------------------------------------------------------------------
    # stream analysis: critical path & structural diff
    # ------------------------------------------------------------------

    def _collect_stream(self, spec: EvalSpec | None = None,
                        **kwargs) -> tuple[EvalSpec, Trace, PIMArch,
                                           Any, Any]:
        """Freshly replay one grid point with a
        :class:`~repro.obs.trace.TimelineCollector` attached and return
        ``(resolved spec, replayed trace, arch, SimResult, collector)``.
        Analysis needs the full replay-order event stream, which memoized
        :meth:`run` results do not carry — so this always replays, but
        through :meth:`BurstSimBackend.collect` with ``ctx=self``, reusing
        every memoized lowering / batching / degraded-trace derivation
        (and priming the on-disk cache like :meth:`run` does).  The
        backend is forced to ``burst-sim`` — the analytic model has no
        event stream to analyze."""
        from repro.obs.trace import TimelineCollector
        if spec is None:
            spec = EvalSpec(backend="burst-sim", **kwargs)
        elif kwargs:
            spec = dataclasses.replace(spec, **kwargs)
        spec = self.resolve(dataclasses.replace(spec, backend="burst-sim"))
        backend = self.backends.get("burst-sim")
        sys_spec = self.systems.get(spec.system)
        arch = sys_spec.make_arch(spec.gbuf_bytes, spec.lbuf_bytes)
        trace = self.trace(spec.workload, spec.system, spec.gbuf_bytes,
                           spec.lbuf_bytes, plan=spec.plan)
        if (self.disk_cache is not None
                and resolve_engine(spec.engine) == "columnar"
                and (spec.faults is None
                     or not spec.faults.has_structural)):
            self._disk_sync(spec, trace, arch)
        collector = TimelineCollector()
        replayed, result = backend.collect(trace, arch, spec, ctx=self,
                                           collector=collector)
        return spec, replayed, arch, result, collector

    def critical_path(self, spec: EvalSpec | None = None, *,
                      cross_check: bool = False, **kwargs) -> Any:
        """Replay one grid point (``EvalSpec`` or its fields as kwargs)
        and walk its critical chain —
        :func:`repro.obs.critpath.critical_path` over a fresh collected
        stream, reconciled against the replay's ``SimResult``.
        ``cross_check=True`` additionally runs the :mod:`repro.check`
        stream verifier first, cross-checking the walker's blocking-edge
        labels against the independent dependency / row replay."""
        from repro.obs.critpath import critical_path as _walk
        spec, trace, arch, result, collector = \
            self._collect_stream(spec, **kwargs)
        meta = {"workload": spec.workload, "system": spec.system,
                "policy": spec.policy, "row_reuse": spec.row_reuse,
                "engine": resolve_engine(spec.engine), "plan": spec.plan}
        if spec.faults is not None:
            meta["faults"] = spec.faults.label()
        return _walk(trace, arch, collector=collector, policy=spec.policy,
                     faults=spec.faults, result=result,
                     cross_check=cross_check, meta=meta)

    def diff(self, spec_a: EvalSpec, spec_b: EvalSpec, *,
             label_a: str | None = None,
             label_b: str | None = None) -> Any:
        """Structurally diff two grid points' replays
        (:func:`repro.obs.diff.diff_timelines`): added / removed /
        shifted work by (aligned layer, kind, bank) provenance plus
        per-resource and makespan deltas.  Default labels name the spec
        fields that differ (``plan=greedy`` vs ``plan=searched``)."""
        from repro.obs.diff import diff_timelines
        ra = self._collect_stream(spec_a)
        rb = self._collect_stream(spec_b)
        if label_a is None or label_b is None:
            sa, sb = ra[0], rb[0]
            fields = [f.name for f in dataclasses.fields(EvalSpec)
                      if getattr(sa, f.name) != getattr(sb, f.name)]
            if fields:
                la = ",".join(f"{n}={getattr(sa, n)}" for n in fields)
                lb = ",".join(f"{n}={getattr(sb, n)}" for n in fields)
            else:
                la, lb = "a", "b"
            label_a = la if label_a is None else label_a
            label_b = lb if label_b is None else label_b
        return diff_timelines(ra[4], rb[4], label_a=label_a,
                              label_b=label_b)

    def sweep(self,
              workloads: str | Iterable[str] | None = None,
              systems: str | Iterable[str] | None = None,
              buffers: Sequence[tuple[int | None, int | None]] | None = None,
              backend: str = "analytic",
              policy: str = "serial",
              row_reuse: bool = True,
              engine: str = "columnar",
              plan: str = "default",
              verify: bool = False,
              faults: "FaultSpec | Sequence[FaultSpec | None] | None"
              = None,
              workers: int = 1,
              point_timeout: float | None = 600.0,
              retries: int = 2,
              retry_backoff: float = 0.5,
              checkpoint: "str | Path | None" = None,
              csv_path: str | None = None,
              verbose: bool = False) -> list[EvalResult]:
        """Evaluate the cross product workloads × systems × buffer points.

        ``None`` axes default to every registered workload / system / the
        per-system default buffer point.  Returns results in grid order.
        ``workers > 1`` farms not-yet-cached points out to a process pool
        (:func:`concurrent.futures.ProcessPoolExecutor`), merges the
        returned results and build stats back into this Experiment's memo
        caches, and still returns deterministic grid order; ``workers <=
        1`` (the default) runs serially in-process.  ``csv_path``
        additionally persists the results (with normalized PPA columns) as
        a CSV artifact via
        :func:`repro.experiment.artifacts.write_results_csv`, so figures
        regenerate without re-running the sweep — plus a per-phase profile
        report (``<csv>.profile.json``, see :mod:`repro.obs.profile`)
        carrying the sweep's cache-stats delta.  ``verbose=True`` logs one
        structured line per grid point to stderr (spec fields, cache
        hit/miss, elapsed seconds) as the sweep progresses — on the
        parallel path workers time each point and the parent prints a
        ``[sweep pool]`` line as each chunk's progress arrives.
        ``verify=True`` (burst-sim points only) runs the
        :mod:`repro.check` schedule verifier after every replay — see
        :class:`~repro.experiment.backends.EvalSpec`.

        ``faults`` extends the grid along the hardware-fault axis: a
        single :class:`~repro.faults.spec.FaultSpec` applies to every
        point, a sequence (``None`` entries allowed for the healthy
        reference) becomes a cross-product axis like ``buffers``.

        Parallel sweeps are supervised: each pool chunk gets a hard
        wall-clock deadline of ``point_timeout`` seconds per grid point
        (``None`` disables), failures are retried up to ``retries`` times
        with exponential ``retry_backoff`` (a crashed worker rebuilds the
        pool first), and a point still failing after that is QUARANTINED
        — reported as a coded failure row in the returned list (negative
        cycles, ``config="FAILED:<code>"``) and on :attr:`failures` —
        instead of aborting the whole sweep.  ``checkpoint`` names an
        append-only :class:`~repro.experiment.journal.SweepJournal` file:
        every completed point is journaled as it lands, and a re-run
        against the same path restores finished points instead of
        re-evaluating them (crash-resume for long sweeps).
        """
        if workloads is None:
            workloads = self.workloads.names()
        elif isinstance(workloads, str):
            workloads = (workloads,)
        if systems is None:
            systems = self.systems.names()
        elif isinstance(systems, str):
            systems = (systems,)
        points = buffers if buffers is not None else ((None, None),)
        fault_axis: tuple = (faults,) \
            if faults is None or isinstance(faults, FaultSpec) \
            else tuple(faults)
        specs = [EvalSpec(workload=w, system=s, gbuf_bytes=g,
                          lbuf_bytes=lb, backend=backend,
                          policy=policy, row_reuse=row_reuse,
                          engine=engine, plan=plan, verify=verify,
                          faults=fl)
                 for w in workloads for s in systems
                 for g, lb in points for fl in fault_axis]
        # the normalization baseline stays on HEALTHY hardware: degraded
        # points report their cost relative to the fault-free paper 1.0
        baselines = [EvalSpec(workload=w, system=self.baseline_system,
                              backend=backend, policy=policy,
                              row_reuse=row_reuse, engine=engine)
                     for w in workloads] if csv_path is not None else []
        journal = None
        if checkpoint is not None:
            from repro.experiment.journal import SweepJournal
            journal = SweepJournal(checkpoint)
            for spec in [*specs, *baselines]:
                resolved = self.resolve(spec)
                if resolved in self._results:
                    continue
                restored = journal.restore(resolved)
                if restored is not None:
                    self._results[resolved] = restored
                    self.stats["journal_restored"] += 1
        # profile the sweep: an already-active profiler (the caller's
        # ``with profiled():``) is reused; otherwise a csv_path sweep
        # activates its own so the report artifact is never empty
        stats_before = dict(self.stats)
        prof = active_profiler()
        scope = profiled() if csv_path is not None and prof is None \
            else contextlib.nullcontext(prof)
        with scope as prof:
            with span("experiment.sweep", points=len(specs),
                      workers=workers):
                results = self._dispatch(specs, workers, baselines,
                                         verbose=verbose,
                                         point_timeout=point_timeout,
                                         retries=retries,
                                         retry_backoff=retry_backoff,
                                         journal=journal)
        if csv_path is not None:
            from repro.experiment.artifacts import write_results_csv
            write_results_csv(csv_path, results, experiment=self)
            if prof is not None:
                delta = {k: v - stats_before.get(k, 0)
                         for k, v in self.stats.items()
                         if v != stats_before.get(k, 0)}
                prof.write_report(
                    Path(csv_path).with_suffix(".profile.json"),
                    meta={"points": len(specs), "workers": workers,
                          "stats_delta": delta})
        return results

    def _failure_result(self, spec: EvalSpec,
                        failure: SweepFailure) -> EvalResult:
        """The coded row a QUARANTINED grid point reports instead of
        aborting the sweep: negative cycles and zero energy/area (no real
        evaluation can produce either), ``config="FAILED:<code>"``, and
        the :class:`SweepFailure` under ``detail["failure"]``.  Never
        memoized into the result cache — a later sweep retries the
        point."""
        from repro.pim.events import EventCounts
        return EvalResult(
            spec=spec, config=f"FAILED:{failure.code}", cycles=-1,
            energy_nj=0.0, area_mm2=0.0, cross_bank_bytes=0,
            events=EventCounts(),
            detail={"failure": failure, "engine": spec.engine})

    def _dispatch(self, specs: Sequence[EvalSpec], workers: int,
                  baselines: Sequence[EvalSpec] = (),
                  verbose: bool = False,
                  point_timeout: float | None = 600.0,
                  retries: int = 2,
                  retry_backoff: float = 0.5,
                  journal: Any = None) -> list[EvalResult]:
        """Evaluate specs in order: one pool pass over the whole batch
        when ``workers > 1`` (plus the ``baselines`` a CSV's normalized
        columns will need — evaluated on the pool rather than serially in
        the parent afterwards), then serve everything from the memo.
        Points the pool QUARANTINED (see :meth:`_run_parallel`) are
        served as coded failure rows, never re-run in the parent — a
        poison point could hang or crash the whole process there."""
        if workers > 1:
            self._run_parallel(list(specs) + list(baselines), workers,
                               verbose=verbose,
                               point_timeout=point_timeout,
                               retries=retries,
                               retry_backoff=retry_backoff,
                               journal=journal)
        results = []
        for k, spec in enumerate(specs):
            resolved = self.resolve(spec)
            failure = self._quarantined.get(resolved)
            if failure is not None:
                results.append(self._failure_result(resolved, failure))
                continue
            cached = resolved in self._results
            t = time.perf_counter()
            result = self.run(resolved)
            elapsed = time.perf_counter() - t
            if journal is not None:
                journal.record_ok(resolved, result)
            results.append(result)
            if verbose:
                print(f"[sweep {k + 1}/{len(specs)}] "
                      f"workload={resolved.workload} "
                      f"system={resolved.system} "
                      f"gbuf={resolved.gbuf_bytes} "
                      f"lbuf={resolved.lbuf_bytes} "
                      f"plan={resolved.plan} policy={resolved.policy} "
                      f"backend={resolved.backend} "
                      f"cached={'yes' if cached else 'no'} "
                      f"elapsed_s={elapsed:.3f}", file=_sys.stderr)
        return results

    def _shippable(self, specs: Sequence[EvalSpec]) -> dict[str, Any] | None:
        """The worker-job template for ``specs`` — pinned plan-override
        records plus a collector prototype — or ``None`` when the points
        cannot be reconstructed in a spawn worker (genuinely custom
        registries, a non-folding collector) and the sweep must stay
        serial.

        Pinned ``plan_overrides`` no longer force the serial path: a
        registry entry that equals the module-level registration modulo
        its overrides ships as :func:`repro.plan.artifacts
        .override_records` and is re-pinned inside each worker."""
        if self.backends is not BACKENDS:
            return None
        collector = self.collector
        if collector is not None and not (hasattr(collector, "fork")
                                          and hasattr(collector, "merge")):
            # a plain collector's replay-order event stream cannot be
            # folded back from a pool; keep replay observable serially
            return None
        for w in {spec.workload for spec in specs}:
            if w not in WORKLOADS or self.workloads.get(w) \
                    is not WORKLOADS.get(w):
                return None
        overrides: list[dict] = []
        from repro.plan.artifacts import override_records
        for s in sorted({spec.system for spec in specs}):
            if s not in SYSTEMS:
                return None
            mine = self.systems.get(s)
            if mine is not SYSTEMS.get(s):
                stripped = dataclasses.replace(mine, plan_overrides=())
                module = dataclasses.replace(SYSTEMS.get(s),
                                             plan_overrides=())
                if stripped != module:
                    return None
            if mine.plan_overrides:
                overrides.extend(override_records(self.systems, names=(s,)))
        return {"overrides": overrides, "collector": collector}

    def _run_parallel(self, specs: Sequence[EvalSpec], workers: int,
                      verbose: bool = False,
                      point_timeout: float | None = 600.0,
                      retries: int = 2,
                      retry_backoff: float = 0.5,
                      journal: Any = None) -> None:
        """Evaluate not-yet-cached specs on a process pool and merge the
        results (plus the workers' build stats, folded collector state and
        per-point progress) into this Experiment.

        Workers rebuild their own Experiment over the MODULE-LEVEL
        registries — re-pinning any shipped plan overrides, re-attaching a
        fork of a :class:`~repro.obs.trace.FoldingCollector`, and reading
        the on-disk cache from the environment — so only genuinely custom
        registries (or a non-folding collector) fall back to the serial
        path.  Points are chunked by fully-resolved grid point —
        (workload, system, gbuf, lbuf, row-reuse, plan) — the unit that
        actually shares a mapped trace and burst lowering across its specs
        (policies / backends / fault scenarios); distinct buffer points
        share nothing, so they parallelize freely even within one system.

        The pool is SUPERVISED: every chunk carries a wall-clock deadline
        (``point_timeout`` seconds × chunk size; ``None`` disables), a
        worker death (``BrokenProcessPool`` — the pool is unusable after
        one) charges the lost chunk when it was alone in flight, else
        requeues all in-flight chunks UNCHARGED on a fresh pool and
        probes them one at a time until the culprit crashes alone (so a
        poison point can never quarantine an innocent bystander), an
        ordinary chunk exception retries with
        exponential backoff, and a hung chunk past its deadline gets its
        pool terminated (a hung worker cannot be cancelled), the
        timed-out chunk charged an attempt and the innocent bystanders
        requeued at their SAME attempt.  A chunk still failing after
        ``retries`` retries is QUARANTINED (:class:`SweepFailure`, stat
        ``sweep_quarantined``) — the sweep completes with coded failure
        rows instead of aborting.  Every merged result and quarantine
        decision is checkpointed into ``journal`` as it lands.
        """
        job_template = self._shippable(specs)
        if job_template is None:
            return
        seen: set[EvalSpec] = set()
        chunks: dict[tuple, list[EvalSpec]] = {}
        for spec in specs:
            spec = self.resolve(spec)
            if spec in self._results or spec in seen \
                    or spec in self._quarantined:
                continue
            seen.add(spec)
            chunks.setdefault(
                (spec.workload, spec.system, spec.gbuf_bytes,
                 spec.lbuf_bytes, spec.row_reuse, spec.plan),
                []).append(spec)
        if not chunks:
            return
        collector = job_template.pop("collector")
        jobs = [dict(job_template, specs=chunk,
                     collector=None if collector is None
                     else collector.fork())
                for chunk in chunks.values()]
        self.stats["parallel_chunks"] += len(jobs)
        self.stats["parallel_points"] += len(seen)
        import collections
        import concurrent.futures
        import multiprocessing
        import sys
        from concurrent.futures.process import BrokenProcessPool
        # spawn, not fork: the surrounding process may hold JAX (or other
        # multithreaded) state that a forked child would deadlock on; the
        # worker only needs the importable module-level registries anyway.
        # Spawn re-executes __main__.__file__ in each worker — mask it
        # when it is a pseudo-file (stdin / REPL pipes), which cannot be
        # re-run and is not needed: _sweep_worker lives in this module.
        main = sys.modules.get("__main__")
        main_file = getattr(main, "__file__", None)
        masked = main_file is not None and not os.path.exists(main_file)
        if masked:
            del main.__file__

        done_n, total = 0, len(seen)
        pending: collections.deque = \
            collections.deque((job, 0) for job in jobs)
        # crash-isolation mode: a BrokenProcessPool with >1 chunk in
        # flight cannot name the culprit, so nobody is charged and the
        # requeued chunks run ONE AT A TIME until a crash happens alone
        # (charged) or a chunk completes (back to full width) — an
        # innocent bystander can never be quarantined by a poison point.
        probe = False

        def merge(payload: dict[str, Any]) -> None:
            nonlocal done_n
            for r in payload["results"]:
                self._results.setdefault(r.spec, r)
                if journal is not None:
                    journal.record_ok(r.spec, r)
            for key, count in payload["stats"].items():
                self.stats[key] = self.stats.get(key, 0) + count
            if collector is not None and payload["collector"] is not None:
                collector.merge(payload["collector"])
            for spec, elapsed in payload["progress"]:
                done_n += 1
                if verbose:
                    print(f"[sweep pool {done_n}/{total}] "
                          f"workload={spec.workload} "
                          f"system={spec.system} "
                          f"gbuf={spec.gbuf_bytes} "
                          f"lbuf={spec.lbuf_bytes} "
                          f"plan={spec.plan} policy={spec.policy} "
                          f"backend={spec.backend} "
                          f"elapsed_s={elapsed:.3f}",
                          file=_sys.stderr)

        def retry_or_quarantine(job: dict, attempt: int, code: str,
                                message: str) -> None:
            if attempt < retries:
                pending.append((job, attempt + 1))
                self.stats["sweep_retries"] += 1
                return
            for spec in job["specs"]:
                failure = SweepFailure(spec=spec, code=code,
                                       message=message,
                                       attempts=attempt + 1)
                self._quarantined[spec] = failure
                self._failed.append(failure)
                self.stats["sweep_quarantined"] += 1
                if journal is not None:
                    journal.record_failure(spec, code, message,
                                           attempt + 1)

        def kill_pool(pool: Any) -> None:
            for p in list((getattr(pool, "_processes", None) or {})
                          .values()):
                with contextlib.suppress(Exception):
                    p.terminate()

        def chunk_label(job: dict) -> str:
            return ", ".join(
                f"{s.workload}/{s.system}/g{s.gbuf_bytes}"
                f"/l{s.lbuf_bytes}/{s.policy}"
                + (f"/{s.faults.label()}" if s.faults is not None else "")
                for s in job["specs"])

        try:
            while pending:
                rebuild = False
                pool = concurrent.futures.ProcessPoolExecutor(
                    max_workers=workers,
                    mp_context=multiprocessing.get_context("spawn"))
                inflight: dict[Any, tuple[dict, int, float]] = {}
                try:
                    while (pending or inflight) and not rebuild:
                        while pending and not (probe and inflight):
                            job, attempt = pending.popleft()
                            if attempt and retry_backoff:
                                time.sleep(retry_backoff
                                           * (2 ** (attempt - 1)))
                            deadline = float("inf") \
                                if point_timeout is None \
                                else (time.monotonic() + point_timeout
                                      * len(job["specs"]))
                            try:
                                fut = pool.submit(_sweep_worker, job)
                            except BrokenProcessPool:
                                pending.appendleft((job, attempt))
                                rebuild = True
                                break
                            inflight[fut] = (job, attempt, deadline)
                        if rebuild or not inflight:
                            break
                        wait_s = None
                        if point_timeout is not None:
                            wait_s = max(
                                0.05,
                                min(dl for _, _, dl in inflight.values())
                                - time.monotonic())
                        ready, _ = concurrent.futures.wait(
                            set(inflight), timeout=wait_s,
                            return_when=concurrent.futures.FIRST_COMPLETED)
                        for fut in ready:
                            job, attempt, _ = inflight.pop(fut)
                            try:
                                payload = fut.result()
                            except BrokenProcessPool:
                                # a worker died (crash/OOM-kill class) and
                                # took the pool with it — every in-flight
                                # chunk is lost.  Alone in flight, the
                                # chunk IS the culprit: charge it.  With
                                # company the blame is ambiguous: requeue
                                # everyone uncharged and probe serially.
                                lost = [(job, attempt)] + \
                                    [(j, a) for j, a, _
                                     in inflight.values()]
                                inflight.clear()
                                if len(lost) == 1:
                                    retry_or_quarantine(
                                        job, attempt, "crash",
                                        "worker process died mid-chunk "
                                        f"(chunk [{chunk_label(job)}])")
                                else:
                                    probe = True
                                    for j, a in lost:
                                        pending.append((j, a))
                                        self.stats["sweep_retries"] += 1
                                rebuild = True
                                break
                            except Exception as exc:
                                retry_or_quarantine(
                                    job, attempt, "error",
                                    f"{type(exc).__name__}: {exc} "
                                    f"(chunk [{chunk_label(job)}])")
                            else:
                                merge(payload)
                                probe = False    # a survivor: end probing
                        if rebuild:
                            break
                        now = time.monotonic()
                        expired = [f for f, (_, _, dl) in inflight.items()
                                   if now >= dl]
                        if expired:
                            # a hung worker cannot be cancelled: kill the
                            # pool's processes, charge the timed-out
                            # chunk(s), requeue the innocent bystanders
                            # at their SAME attempt and rebuild
                            kill_pool(pool)
                            for f in expired:
                                job, attempt, _ = inflight.pop(f)
                                self.stats["sweep_timeouts"] += 1
                                retry_or_quarantine(
                                    job, attempt, "timeout",
                                    f"grid point(s) [{chunk_label(job)}] "
                                    "exceeded the "
                                    f"{point_timeout:.0f}s/point "
                                    "wall-clock deadline")
                            for _, (j, a, _) in inflight.items():
                                pending.append((j, a))
                            inflight.clear()
                            rebuild = True
                finally:
                    if rebuild:
                        kill_pool(pool)
                        pool.shutdown(wait=False, cancel_futures=True)
                    else:
                        pool.shutdown(wait=True)
        finally:
            if masked:
                main.__file__ = main_file

    def pareto_frontier(self,
                        workload: str,
                        systems: str | Iterable[str] | None = None,
                        gbufs: Sequence[int | None] = (None,),
                        lbufs: Sequence[int | None] = (None,),
                        backend: str = "burst-sim",
                        policy: str | Sequence[str] = "row-aware",
                        row_reuse: bool | Sequence[bool] = True,
                        engine: str = "columnar",
                        plan: str | Sequence[str] = "default",
                        workers: int = 1,
                        csv_path: str | None = None) -> list[ParetoPoint]:
        """Sweep the (GBUF, LBUF, system) design grid for one workload and
        tag each point as Pareto-dominated or not over the PPA triple
        (cycles, energy, area) — the frontier the paper's buffer-sizing
        argument walks.  ``policy`` / ``row_reuse`` / ``plan`` also accept
        sequences, extending the grid along the issue-policy, row-reuse
        and fusion-plan axes (dominance is tagged across the WHOLE grid,
        so e.g. a searched plan can knock a greedy point off the
        frontier).  Returns every grid point in sweep order (filter on
        ``dominated`` for the frontier); ``csv_path`` persists the tagged
        grid via :func:`repro.experiment.artifacts.write_pareto_csv`.

        The plan axis only emits plan values that RESOLVE to distinct
        partitions at each (system, buffer) point (a layer-by-layer
        system ignores the knob entirely; on fused systems e.g.
        ``"default"`` with no pinned override ≡ ``"greedy"``, and the
        searched optimum sometimes IS the greedy plan) — otherwise the
        grid would carry physically identical duplicate points, each
        shielding the other from dominance (ties dominate nothing)."""
        policies = (policy,) if isinstance(policy, str) else tuple(policy)
        modes = (row_reuse,) if isinstance(row_reuse, bool) \
            else tuple(row_reuse)
        plans = (plan,) if isinstance(plan, str) else tuple(plan)
        if systems is None:
            systems = self.systems.names()
        elif isinstance(systems, str):
            systems = (systems,)
        # plan values deduped by the partition they resolve to, per
        # (system, resolved buffer point); plan resolution is independent
        # of policy/row-reuse, so this is computed once per point
        combos: list[tuple[str, int | None, int | None, str]] = []
        seen: set[tuple] = set()
        for s in systems:
            sys_spec = self.systems.get(s)
            g0, l0 = sys_spec.default_buffers
            for g in gbufs:
                for lb in lbufs:
                    rg = g0 if g is None else g
                    rl = l0 if lb is None else lb
                    for pl in plans:
                        sig = None if sys_spec.tile_grid is None else \
                            self.plan(workload, sys_spec.tile_grid,
                                      system=s, source=pl, gbuf_bytes=rg,
                                      lbuf_bytes=rl).signature()
                        key = (s, rg, rl, sig)
                        if key in seen:
                            continue
                        seen.add(key)
                        combos.append((s, g, lb, pl))
        specs = [EvalSpec(workload=workload, system=s, gbuf_bytes=g,
                          lbuf_bytes=lb, backend=backend, policy=pol,
                          row_reuse=rr, engine=engine, plan=pl)
                 for pol in policies for rr in modes
                 for s, g, lb, pl in combos]
        # ONE pool pass over the whole extended grid: specs differing
        # only in policy chunk onto the same worker (shared trace +
        # lowering), instead of a fresh pool per axis combo
        baselines = [EvalSpec(workload=workload,
                              system=self.baseline_system,
                              backend=backend, policy=pol, row_reuse=rr,
                              engine=engine)
                     for pol in policies for rr in modes] \
            if csv_path is not None else []
        results = self._dispatch(specs, workers, baselines)
        points = [ParetoPoint(result=r, dominated=d)
                  for r, d in zip(results, pareto_tags(results))]
        if csv_path is not None:
            from repro.experiment.artifacts import write_pareto_csv
            write_pareto_csv(csv_path, points, experiment=self)
        return points


# ---------------------------------------------------------------------------
# process-wide default (shared cache behind the legacy pim.ppa shims)
# ---------------------------------------------------------------------------

_DEFAULT: Experiment | None = None


def default_experiment() -> Experiment:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Experiment()
    return _DEFAULT
