"""The `Experiment` driver: memoized ``run()`` / ``sweep()`` over the grid.

One call path evaluates any (registered workload × system × buffer config)
under any registered backend.  Work that is invariant across sweep points
is computed once and reused:

* **graphs** — one build per workload (the legacy path rebuilt the graph
  on every ``evaluate()`` call, including once per normalisation baseline),
* **fusion plans and group tilings** — one per (workload, tile grid);
  tilings are buffer-independent, so a (GBUF, LBUF) sweep never re-tiles,
* **mapped traces** — one per (workload, system, gbuf, lbuf); the
  normalisation baseline is one of these, shared by every point,
* **lowered burst traces** — one per (trace, arch), shared across issue
  policies (the lowering dominates burst-sim cost on big traces),
* **results** — one backend evaluation per resolved spec.

``Experiment.stats`` counts builds vs cache hits; tests assert on it.
A process-wide :func:`default_experiment` backs the legacy
``repro.pim.ppa`` shims so old and new entry points share one cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

from repro.core import dataflow
from repro.core.commands import Trace
from repro.core.fusion import FusionPlan, plan_fused
from repro.core.graph import Graph
from repro.pim.arch import PIMArch
from repro.experiment import systems as _systems  # registers built-ins
from repro.experiment import workloads as _workloads  # registers built-ins
from repro.experiment.backends import BACKENDS, EvalResult, EvalSpec
from repro.experiment.registry import (Registry, SystemSpec, WorkloadSpec,
                                       SYSTEMS, WORKLOADS)

BASELINE_SYSTEM = _systems.BASELINE_SYSTEM

_ = _workloads  # imported for registration side effects


class Experiment:
    """Declarative, memoizing evaluation driver over the registries."""

    def __init__(self,
                 workloads: Registry[WorkloadSpec] = WORKLOADS,
                 systems: Registry[SystemSpec] = SYSTEMS,
                 backends: Registry = BACKENDS,
                 baseline_system: str = BASELINE_SYSTEM) -> None:
        self.workloads = workloads
        self.systems = systems
        self.backends = backends
        self.baseline_system = baseline_system
        self.stats: dict[str, int] = {
            "graph_builds": 0, "plan_builds": 0, "tiling_builds": 0,
            "trace_maps": 0, "trace_hits": 0, "lowerings": 0,
            "cycle_models": 0, "energy_models": 0,
            "backend_evals": 0, "result_hits": 0,
        }
        self._graphs: dict[str, Graph] = {}
        self._plans: dict[tuple[str, int, int], FusionPlan] = {}
        self._tilings: dict[tuple[str, int, int], dict] = {}
        self._traces: dict[tuple[str, str, int, int], Trace] = {}
        # identity-keyed per-(trace, arch[, extra]) derivations (lowered
        # bursts keyed by row-reuse mode, analytic cycle/energy reports):
        # {key: (trace_ref, value)} — the stored strong ref both keeps the
        # id() stable and lets the lookup verify it still names the same
        # trace object
        self._lowered: dict[tuple, tuple[Trace, Any]] = {}
        self._cycle_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._energy_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._results: dict[EvalSpec, EvalResult] = {}

    # ------------------------------------------------------------------
    # memoized build pipeline
    # ------------------------------------------------------------------

    def graph(self, workload: str) -> Graph:
        """The workload's graph, built once per Experiment (treat as
        read-only — every trace and result shares it)."""
        g = self._graphs.get(workload)
        if g is None:
            g = self.workloads.get(workload).build()
            self.stats["graph_builds"] += 1
            self._graphs[workload] = g
        return g

    def plan(self, workload: str, tile_grid: tuple[int, int]) -> FusionPlan:
        key = (workload, *tile_grid)
        p = self._plans.get(key)
        if p is None:
            p = plan_fused(self.graph(workload), *tile_grid)
            self.stats["plan_builds"] += 1
            self._plans[key] = p
        return p

    def tilings(self, workload: str, tile_grid: tuple[int, int]) -> dict:
        """Buffer-independent tiling solutions for every fused group —
        the expensive geometry a (GBUF, LBUF) sweep must never redo."""
        key = (workload, *tile_grid)
        t = self._tilings.get(key)
        if t is None:
            t = dataflow.plan_tilings(self.plan(workload, tile_grid))
            self.stats["tiling_builds"] += 1
            self._tilings[key] = t
        return t

    def trace(self, workload: str, system: str, gbuf_bytes: int,
              lbuf_bytes: int) -> Trace:
        """The mapped command trace for one fully-resolved grid point."""
        key = (workload, system, gbuf_bytes, lbuf_bytes)
        tr = self._traces.get(key)
        if tr is not None:
            self.stats["trace_hits"] += 1
            return tr
        spec = self.systems.get(system)
        arch = spec.make_arch(gbuf_bytes, lbuf_bytes)
        if spec.tile_grid is None:
            tr = dataflow.map_baseline(self.graph(workload), arch)
        else:
            tr = dataflow.map_pimfused(self.plan(workload, spec.tile_grid),
                                       arch,
                                       tilings=self.tilings(workload,
                                                            spec.tile_grid))
        self.stats["trace_maps"] += 1
        self._traces[key] = tr
        return tr

    def _per_trace(self, cache: dict, trace: Trace, arch: PIMArch,
                   build, stat: str, extra: Any = None) -> Any:
        key = (id(trace), arch.name, arch.gbuf_bytes, arch.lbuf_bytes, extra)
        hit = cache.get(key)
        if hit is not None and hit[0] is trace:
            return hit[1]
        value = build()
        self.stats[stat] += 1
        cache[key] = (trace, value)
        return value

    def lowered(self, trace: Trace, arch: PIMArch,
                row_reuse: bool = True) -> Any:
        """Burst-lowered trace, shared across issue policies and keyed by
        row-reuse mode (:class:`repro.experiment.backends.EvalContext`
        hook)."""
        from repro.sim.burst import lower_trace
        return self._per_trace(self._lowered, trace, arch,
                               lambda: lower_trace(trace, arch,
                                                   row_reuse=row_reuse),
                               "lowerings", extra=row_reuse)

    def cycle_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic cycle report, policy-independent — computed once per
        (trace, arch) however many backends/policies consume it."""
        from repro.pim.timing import simulate_cycles
        return self._per_trace(self._cycle_reports, trace, arch,
                               lambda: simulate_cycles(trace, arch),
                               "cycle_models")

    def energy_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic energy report, policy-independent (as above)."""
        from repro.pim.energy import simulate_energy
        return self._per_trace(self._energy_reports, trace, arch,
                               lambda: simulate_energy(trace, arch),
                               "energy_models")

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------

    def resolve(self, spec: EvalSpec) -> EvalSpec:
        """Fill unset buffer sizes from the system's default design point."""
        sys_spec = self.systems.get(spec.system)
        g0, l0 = sys_spec.default_buffers
        return dataclasses.replace(
            spec,
            gbuf_bytes=g0 if spec.gbuf_bytes is None else spec.gbuf_bytes,
            lbuf_bytes=l0 if spec.lbuf_bytes is None else spec.lbuf_bytes)

    def run(self, spec: EvalSpec | None = None, **kwargs) -> EvalResult:
        """Evaluate one grid point (``EvalSpec`` or its fields as kwargs)."""
        if spec is None:
            spec = EvalSpec(**kwargs)
        elif kwargs:
            spec = dataclasses.replace(spec, **kwargs)
        spec = self.resolve(spec)
        cached = self._results.get(spec)
        if cached is not None:
            self.stats["result_hits"] += 1
            return cached
        backend = self.backends.get(spec.backend)
        sys_spec = self.systems.get(spec.system)
        arch = sys_spec.make_arch(spec.gbuf_bytes, spec.lbuf_bytes)
        trace = self.trace(spec.workload, spec.system, spec.gbuf_bytes,
                           spec.lbuf_bytes)
        result = backend.evaluate(trace, arch, spec, ctx=self)
        self.stats["backend_evals"] += 1
        self._results[spec] = result
        return result

    def baseline(self, workload: str, backend: str = "analytic",
                 policy: str = "serial",
                 row_reuse: bool = True) -> EvalResult:
        """The paper's 1.0: the baseline system at its own design point,
        evaluated under the SAME backend/policy/row-reuse mode as the
        results it scales."""
        return self.run(EvalSpec(workload=workload,
                                 system=self.baseline_system,
                                 backend=backend, policy=policy,
                                 row_reuse=row_reuse))

    def normalized(self, result: EvalResult) -> dict[str, float]:
        """Normalize one result to its workload's baseline (memoized — the
        baseline is evaluated once per workload, not once per point)."""
        return result.normalized(self.baseline(result.workload,
                                               backend=result.spec.backend,
                                               policy=result.spec.policy,
                                               row_reuse=result.spec.row_reuse))

    def sweep(self,
              workloads: str | Iterable[str] | None = None,
              systems: str | Iterable[str] | None = None,
              buffers: Sequence[tuple[int | None, int | None]] | None = None,
              backend: str = "analytic",
              policy: str = "serial",
              row_reuse: bool = True,
              csv_path: str | None = None) -> list[EvalResult]:
        """Evaluate the cross product workloads × systems × buffer points.

        ``None`` axes default to every registered workload / system / the
        per-system default buffer point.  Returns results in grid order.
        ``csv_path`` additionally persists the results (with normalized
        PPA columns) as a CSV artifact via
        :func:`repro.experiment.artifacts.write_results_csv`, so figures
        regenerate without re-running the sweep.
        """
        if workloads is None:
            workloads = self.workloads.names()
        elif isinstance(workloads, str):
            workloads = (workloads,)
        if systems is None:
            systems = self.systems.names()
        elif isinstance(systems, str):
            systems = (systems,)
        points = buffers if buffers is not None else ((None, None),)
        results = [self.run(EvalSpec(workload=w, system=s, gbuf_bytes=g,
                                     lbuf_bytes=l, backend=backend,
                                     policy=policy, row_reuse=row_reuse))
                   for w in workloads for s in systems for g, l in points]
        if csv_path is not None:
            from repro.experiment.artifacts import write_results_csv
            write_results_csv(csv_path, results, experiment=self)
        return results


# ---------------------------------------------------------------------------
# process-wide default (shared cache behind the legacy pim.ppa shims)
# ---------------------------------------------------------------------------

_DEFAULT: Experiment | None = None


def default_experiment() -> Experiment:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Experiment()
    return _DEFAULT
