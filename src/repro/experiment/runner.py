"""The `Experiment` driver: memoized ``run()`` / ``sweep()`` over the grid.

One call path evaluates any (registered workload × system × buffer config)
under any registered backend.  Work that is invariant across sweep points
is computed once and reused:

* **graphs** — one build per workload (the legacy path rebuilt the graph
  on every ``evaluate()`` call, including once per normalisation baseline),
* **fusion plans and group tilings** — one per (workload, tile grid);
  tilings are buffer-independent, so a (GBUF, LBUF) sweep never re-tiles,
* **mapped traces** — one per (workload, system, gbuf, lbuf); the
  normalisation baseline is one of these, shared by every point,
* **lowered burst traces** — one per (trace, arch), shared across issue
  policies (the lowering dominates burst-sim cost on big traces),
* **results** — one backend evaluation per resolved spec.

``Experiment.stats`` counts builds vs cache hits; tests assert on it.
A process-wide :func:`default_experiment` backs the legacy
``repro.pim.ppa`` shims so old and new entry points share one cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import sys as _sys
import time
from pathlib import Path
from typing import Any, Iterable, Sequence

from repro.core import dataflow
from repro.core.commands import Trace
from repro.core.fusion import (FusionPlan, PlanSig, plan_from_signature,
                               plan_fused)
from repro.core.graph import Graph
from repro.experiment import systems as _systems  # registers built-ins
from repro.experiment import workloads as _workloads  # registers built-ins
from repro.experiment.backends import (BACKENDS, EvalResult, EvalSpec,
                                       resolve_engine)
from repro.experiment.registry import (SYSTEMS, WORKLOADS, Registry,
                                       SystemSpec, WorkloadSpec)
from repro.obs.counters import CounterRegistry
from repro.obs.profile import active_profiler, profiled, span
from repro.pim.arch import PIMArch

BASELINE_SYSTEM = _systems.BASELINE_SYSTEM

_ = _workloads  # imported for registration side effects


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One design point of a :meth:`Experiment.pareto_frontier` sweep,
    tagged with whether another grid point Pareto-dominates it on the
    (cycles, energy, area) triple."""

    result: EvalResult
    dominated: bool


def _dominates(a: tuple[float, ...], b: tuple[float, ...]) -> bool:
    """True if ``a`` is no worse than ``b`` everywhere and strictly better
    somewhere (ties dominate nothing — duplicate points both survive)."""
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


def pareto_tags(results: Sequence[EvalResult]) -> list[bool]:
    """Per-result dominated flags over (cycles, energy_nj, area_mm2) —
    smaller is better on every axis."""
    metrics = [(r.cycles, r.energy_nj, r.area_mm2) for r in results]
    return [any(_dominates(other, mine)
                for j, other in enumerate(metrics) if j != i)
            for i, mine in enumerate(metrics)]


def _sweep_worker(job: dict[str, Any]) -> dict[str, Any]:
    """Process-pool entry point for :meth:`Experiment.sweep`: evaluate one
    chunk of grid points on a fresh Experiment (over the module-level
    registries, with the parent's pinned plan overrides re-applied from
    the shipped :func:`repro.plan.artifacts.override_records`) and ship
    the results, build stats, folded collector and per-point progress
    back for the parent to merge.  The worker's Experiment reads the
    on-disk cache from the environment, so spawn pools stop re-lowering
    the same trace once any process has stored it."""
    exp = Experiment()
    if job.get("overrides"):
        from repro.plan.artifacts import apply_override_records
        apply_override_records(exp.systems, job["overrides"])
    collector = job.get("collector")
    if collector is not None:
        exp.collector = collector
    results: list[EvalResult] = []
    progress: list[tuple[EvalSpec, float]] = []
    for spec in job["specs"]:
        t0 = time.perf_counter()
        results.append(exp.run(spec))
        progress.append((spec, time.perf_counter() - t0))
    return {"results": results, "stats": dict(exp.stats),
            "collector": collector, "progress": progress}


class Experiment:
    """Declarative, memoizing evaluation driver over the registries."""

    def __init__(self,
                 workloads: Registry[WorkloadSpec] = WORKLOADS,
                 systems: Registry[SystemSpec] = SYSTEMS,
                 backends: Registry = BACKENDS,
                 baseline_system: str = BASELINE_SYSTEM,
                 disk_cache: Any = "env") -> None:
        self.workloads = workloads
        self.systems = systems
        self.backends = backends
        self.baseline_system = baseline_system
        # on-disk cache for columnar lowerings / batch orders: the default
        # sentinel reads $REPRO_CACHE_DIR / $REPRO_CACHE (off unless opted
        # in); pass a DiskCache to force one, or None to disable
        if disk_cache == "env":
            from repro.experiment.cache import DiskCache
            disk_cache = DiskCache.from_env()
        self.disk_cache = disk_cache
        # a CounterRegistry IS a MutableMapping, so dict-style call sites
        # (tests assert stats["trace_hits"], dict(exp.stats)) keep working
        # while gaining the namespaced snapshot/JSON API of repro.obs
        self.stats: CounterRegistry = CounterRegistry({
            "graph_builds": 0, "plan_builds": 0, "plan_searches": 0,
            "tiling_builds": 0,
            "trace_maps": 0, "trace_hits": 0, "lowerings": 0,
            "columnar_lowerings": 0, "batchings": 0,
            "cycle_models": 0, "energy_models": 0,
            "backend_evals": 0, "result_hits": 0,
            "disk_hits": 0, "disk_misses": 0, "disk_stores": 0,
            "parallel_chunks": 0, "parallel_points": 0,
        })
        # optional repro.obs.trace.TraceCollector: when set, the burst-sim
        # backend streams replay events into it (EvalContext hook).  NOTE:
        # memoized results do not re-replay — attach the collector before
        # the point of interest is first evaluated (or use a fresh
        # Experiment, as benchmarks/bottleneck_report.py does).  A
        # FoldingCollector (fork()/merge()) also rides sweep(workers=N)
        # pools; any other collector keeps those sweeps serial.
        self.collector: Any = None
        self._graphs: dict[str, Graph] = {}
        self._plans: dict[tuple, FusionPlan] = {}
        self._searches: dict[tuple[str, str, int, int], Any] = {}
        self._tilings: dict[tuple[str, PlanSig], dict] = {}
        self._traces: dict[tuple, Trace] = {}
        # identity-keyed per-(trace, arch[, extra]) derivations (lowered
        # bursts keyed by row-reuse mode, analytic cycle/energy reports):
        # {key: (trace_ref, value)} — the stored strong ref both keeps the
        # id() stable and lets the lookup verify it still names the same
        # trace object
        self._lowered: dict[tuple, tuple[Trace, Any]] = {}
        self._columnar: dict[tuple, tuple[Trace, Any]] = {}
        self._batched: dict[tuple, tuple[Trace, Any]] = {}
        self._cycle_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._energy_reports: dict[tuple, tuple[Trace, Any]] = {}
        self._results: dict[EvalSpec, EvalResult] = {}

    # ------------------------------------------------------------------
    # memoized build pipeline
    # ------------------------------------------------------------------

    def graph(self, workload: str) -> Graph:
        """The workload's graph, built once per Experiment (treat as
        read-only — every trace and result shares it)."""
        g = self._graphs.get(workload)
        if g is None:
            g = self.workloads.get(workload).build()
            self.stats["graph_builds"] += 1
            self._graphs[workload] = g
        return g

    def plan(self, workload: str, tile_grid: tuple[int, int],
             system: str | None = None, source: str = "default",
             gbuf_bytes: int | None = None,
             lbuf_bytes: int | None = None) -> FusionPlan:
        """The fusion plan for a workload on a tile grid.

        ``source`` selects how the partition is decided (the
        ``EvalSpec.plan`` knob): ``"greedy"`` is the paper's rule;
        ``"default"`` additionally honors the system's pinned per-workload
        override (:attr:`SystemSpec.plan_overrides`) when ``system`` is
        given; ``"searched"`` is the DP optimum of
        :meth:`search_plan` at the (resolved) buffer point — the only
        source whose plan depends on buffer sizes.
        """
        if source == "searched":
            if system is None:
                raise ValueError("plan source 'searched' needs the system "
                                 "(the search costs its arch)")
            return self.search_plan(workload, system, gbuf_bytes,
                                    lbuf_bytes).plan
        if source not in ("default", "greedy"):
            raise ValueError(f"unknown plan source {source!r}; choose from "
                             "['default', 'greedy', 'searched']")
        if source == "default" and system is not None:
            sig = self.systems.get(system).plan_override(workload)
            if sig is not None:
                # keyed by the SIGNATURE, not the system: re-registering a
                # spec with a different override can never serve a stale
                # plan, and systems sharing an override share the build
                key = ("override", workload, sig)
                p = self._plans.get(key)
                if p is None:
                    p = plan_from_signature(self.graph(workload), sig)
                    self.stats["plan_builds"] += 1
                    self._plans[key] = p
                return p
        key = ("greedy", workload, *tile_grid)
        p = self._plans.get(key)
        if p is None:
            p = plan_fused(self.graph(workload), *tile_grid)
            self.stats["plan_builds"] += 1
            self._plans[key] = p
        return p

    def search_plan(self, workload: str, system: str,
                    gbuf_bytes: int | None = None,
                    lbuf_bytes: int | None = None,
                    trace_cost=None) -> Any:
        """DP-search the fusion partition for (workload, system) at a
        buffer point (defaults: the system's design point) — returns the
        :class:`repro.plan.dp.SearchResult` (memoized per resolved point;
        custom ``trace_cost`` callables bypass the memo)."""
        spec = self.systems.get(system)
        if spec.tile_grid is None:
            raise ValueError(f"system {system!r} runs the layer-by-layer "
                             "dataflow; there is no partition to search")
        g0, l0 = spec.default_buffers
        gbuf = g0 if gbuf_bytes is None else gbuf_bytes
        lbuf = l0 if lbuf_bytes is None else lbuf_bytes
        key = (workload, system, gbuf, lbuf)
        if trace_cost is None:
            hit = self._searches.get(key)
            if hit is not None:
                return hit
        from repro.plan.dp import search_partition
        with span("plan.search", workload=workload, system=system):
            sr = search_partition(self.graph(workload),
                                  spec.make_arch(gbuf, lbuf),
                                  *spec.tile_grid, trace_cost=trace_cost)
        self.stats["plan_searches"] += 1
        if trace_cost is None:
            self._searches[key] = sr
        return sr

    def pin_plan(self, workload: str, system: str,
                 plan: FusionPlan | None = None) -> "SystemSpec":
        """Pin a fusion plan as the system's per-workload override, so
        ``plan="default"`` specs reproduce it from now on.  ``plan=None``
        searches first (:meth:`search_plan` at the system's design point).
        Re-registers the system spec (in THIS experiment's registry — pass
        ``SYSTEMS.clone()`` to the constructor to keep the process-wide
        registry untouched) and drops the caches the override invalidates.
        """
        spec = self.systems.get(system)
        if plan is None:
            plan = self.search_plan(workload, system).plan
        graph = self.graph(workload)
        if plan.graph.name != graph.name or len(plan.graph) != len(graph):
            raise ValueError(
                f"plan was built for graph {plan.graph.name!r} "
                f"({len(plan.graph)} layers), not workload {workload!r} "
                f"({graph.name!r}, {len(graph)} layers)")
        new_spec = spec.with_plan_override(workload, plan.signature())
        self.systems.register(system, new_spec, replace=True)
        self._traces = {k: v for k, v in self._traces.items()
                        if not (k[0] == workload and k[1] == system)}
        self._results = {s: r for s, r in self._results.items()
                         if not (s.workload == workload
                                 and s.system == system
                                 and s.plan == "default")}
        return new_spec

    def tilings(self, workload: str, tile_grid: tuple[int, int],
                plan: FusionPlan | None = None) -> dict:
        """Buffer-independent tiling solutions for every fused group —
        the expensive geometry a (GBUF, LBUF) sweep must never redo.
        Keyed by the plan's signature, so every plan source (greedy,
        override, searched) shares tilings for identical partitions."""
        if plan is None:
            plan = self.plan(workload, tile_grid)
        key = (workload, plan.signature())
        t = self._tilings.get(key)
        if t is None:
            t = dataflow.plan_tilings(plan)
            self.stats["tiling_builds"] += 1
            self._tilings[key] = t
        return t

    def trace(self, workload: str, system: str, gbuf_bytes: int,
              lbuf_bytes: int, plan: str = "default") -> Trace:
        """The mapped command trace for one fully-resolved grid point.
        Keyed by the RESOLVED plan signature, so plan sources that agree
        on the partition share one trace."""
        spec = self.systems.get(system)
        fused_plan: FusionPlan | None = None
        if spec.tile_grid is None:
            plan_key = None
        else:
            fused_plan = self.plan(workload, spec.tile_grid, system=system,
                                   source=plan, gbuf_bytes=gbuf_bytes,
                                   lbuf_bytes=lbuf_bytes)
            plan_key = fused_plan.signature()
        key = (workload, system, gbuf_bytes, lbuf_bytes, plan_key)
        tr = self._traces.get(key)
        if tr is not None:
            self.stats["trace_hits"] += 1
            return tr
        arch = spec.make_arch(gbuf_bytes, lbuf_bytes)
        with span("experiment.map", workload=workload, system=system):
            if fused_plan is None:
                tr = dataflow.map_baseline(self.graph(workload), arch)
            else:
                tr = dataflow.map_pimfused(
                    fused_plan, arch,
                    tilings=self.tilings(workload, spec.tile_grid,
                                         plan=fused_plan))
        self.stats["trace_maps"] += 1
        self._traces[key] = tr
        return tr

    def _per_trace(self, cache: dict, trace: Trace, arch: PIMArch,
                   build, stat: str, extra: Any = None,
                   load=None, store=None) -> Any:
        """``load``/``store`` are the optional on-disk hooks wired by
        :meth:`_disk_sync`: on an in-memory miss, ``load()`` is tried
        first (a non-``None`` return is a disk hit), otherwise ``build()``
        runs and ``store(value)`` persists it."""
        key = (id(trace), arch.name, arch.gbuf_bytes, arch.lbuf_bytes, extra)
        hit = cache.get(key)
        if hit is not None and hit[0] is trace:
            return hit[1]
        value = None
        if load is not None:
            value = load()
            self.stats["disk_hits" if value is not None
                       else "disk_misses"] += 1
        if value is None:
            # one span per derivation family: experiment.lowerings,
            # experiment.batchings, experiment.cycle_models, ...
            with span(f"experiment.{stat}"):
                value = build()
            self.stats[stat] += 1
            if store is not None:
                store(value)
                self.stats["disk_stores"] += 1
        cache[key] = (trace, value)
        return value

    def lowered(self, trace: Trace, arch: PIMArch,
                row_reuse: bool = True) -> Any:
        """Burst-lowered trace, shared across issue policies and keyed by
        row-reuse mode (:class:`repro.experiment.backends.EvalContext`
        hook)."""
        from repro.sim.burst import lower_trace
        return self._per_trace(self._lowered, trace, arch,
                               lambda: lower_trace(trace, arch,
                                                   row_reuse=row_reuse),
                               "lowerings", extra=row_reuse)

    def columnar(self, trace: Trace, arch: PIMArch,
                 row_reuse: bool = True, load=None, store=None) -> Any:
        """Columnar (structure-of-arrays) burst lowering for the fast-path
        engine — cached like :meth:`lowered`, and built directly from the
        trace (vectorized emission, no intermediate ``BurstOp`` objects).
        ``load``/``store`` are :meth:`_disk_sync`'s on-disk hooks."""
        from repro.sim.burst import lower_trace_columnar
        return self._per_trace(self._columnar, trace, arch,
                               lambda: lower_trace_columnar(
                                   trace, arch, row_reuse=row_reuse),
                               "columnar_lowerings", extra=row_reuse,
                               load=load, store=store)

    def batched(self, trace: Trace, arch: PIMArch, row_reuse: bool,
                policy: str, engine: str, load=None, store=None) -> Any:
        """Batched burst ordering for a batching policy (``row-aware``),
        cached per (lowering, policy) so a multi-policy sweep sorts each
        command's bursts once instead of once per ``simulate()`` call.
        ``load``/``store`` are :meth:`_disk_sync`'s on-disk hooks."""
        def build():
            if engine == "columnar":
                from repro.sim.scheduler import batch_same_row_columnar
                return batch_same_row_columnar(
                    self.columnar(trace, arch, row_reuse), policy)
            from repro.sim.scheduler import batch_same_row
            return [batch_same_row(ops)
                    for ops in self.lowered(trace, arch, row_reuse)]
        return self._per_trace(self._batched, trace, arch, build,
                               "batchings",
                               extra=(row_reuse, policy, engine),
                               load=load, store=store)

    def cycle_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic cycle report, policy-independent — computed once per
        (trace, arch) however many backends/policies consume it."""
        from repro.pim.timing import simulate_cycles
        return self._per_trace(self._cycle_reports, trace, arch,
                               lambda: simulate_cycles(trace, arch),
                               "cycle_models")

    def energy_report(self, trace: Trace, arch: PIMArch) -> Any:
        """Analytic energy report, policy-independent (as above)."""
        from repro.pim.energy import simulate_energy
        return self._per_trace(self._energy_reports, trace, arch,
                               lambda: simulate_energy(trace, arch),
                               "energy_models")

    def counters(self) -> CounterRegistry:
        """Point-in-time :class:`~repro.obs.counters.CounterRegistry` with
        the experiment's cache stats under the ``experiment.*`` namespace
        (a copy — mutate :attr:`stats` for live counting).  Callers merge
        per-replay counters in via
        :func:`repro.obs.counters.counters_from_sim_result`."""
        reg = CounterRegistry()
        reg.merge(self.stats, prefix="experiment")
        return reg

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------

    def resolve(self, spec: EvalSpec) -> EvalSpec:
        """Fill unset buffer sizes from the system's default design point."""
        sys_spec = self.systems.get(spec.system)
        g0, l0 = sys_spec.default_buffers
        return dataclasses.replace(
            spec,
            gbuf_bytes=g0 if spec.gbuf_bytes is None else spec.gbuf_bytes,
            lbuf_bytes=l0 if spec.lbuf_bytes is None else spec.lbuf_bytes)

    def _disk_sync(self, spec: EvalSpec, trace: Trace,
                   arch: PIMArch) -> None:
        """Prime the in-memory columnar/batched memos from the on-disk
        cache (or persist fresh builds into it) for one resolved burst-sim
        grid point — the one place workload / system / resolved plan
        signature are all known, so the content-addressed key can be
        formed.  The backend's later ``ctx.columnar`` / ``ctx.batched``
        calls then hit the primed memo."""
        from repro.experiment.cache import LOWERING_VERSION, arch_fingerprint
        from repro.sim.scheduler import BATCHING_POLICIES, seed_batched
        dc = self.disk_cache
        sys_spec = self.systems.get(spec.system)
        plan_sig: Any = None
        if sys_spec.tile_grid is not None:
            plan_sig = self.plan(spec.workload, sys_spec.tile_grid,
                                 system=spec.system, source=spec.plan,
                                 gbuf_bytes=spec.gbuf_bytes,
                                 lbuf_bytes=spec.lbuf_bytes).signature()
        base_key = dc.key_for(
            kind="columnar", version=LOWERING_VERSION,
            workload=spec.workload, system=spec.system,
            plan=plan_sig, row_reuse=spec.row_reuse,
            arch=arch_fingerprint(arch))
        cols = self.columnar(
            trace, arch, spec.row_reuse,
            load=lambda: dc.load_columnar(base_key, trace, arch),
            store=lambda c: dc.store_columnar(base_key, c))
        if spec.policy not in BATCHING_POLICIES:
            return
        order_key = dc.key_for(kind="batch-order", base=base_key,
                               policy=spec.policy)

        def load() -> Any:
            order = dc.load_order(order_key, cols)
            if order is None:
                return None
            return seed_batched(cols, spec.policy, order)

        self.batched(trace, arch, spec.row_reuse, spec.policy, "columnar",
                     load=load,
                     store=lambda b: dc.store_order(order_key,
                                                    b.batch_order))

    def run(self, spec: EvalSpec | None = None, **kwargs) -> EvalResult:
        """Evaluate one grid point (``EvalSpec`` or its fields as kwargs)."""
        if spec is None:
            spec = EvalSpec(**kwargs)
        elif kwargs:
            spec = dataclasses.replace(spec, **kwargs)
        spec = self.resolve(spec)
        cached = self._results.get(spec)
        if cached is not None:
            self.stats["result_hits"] += 1
            return cached
        backend = self.backends.get(spec.backend)
        sys_spec = self.systems.get(spec.system)
        arch = sys_spec.make_arch(spec.gbuf_bytes, spec.lbuf_bytes)
        trace = self.trace(spec.workload, spec.system, spec.gbuf_bytes,
                           spec.lbuf_bytes, plan=spec.plan)
        if (self.disk_cache is not None and spec.backend == "burst-sim"
                and resolve_engine(spec.engine) == "columnar"):
            self._disk_sync(spec, trace, arch)
        with span("experiment.evaluate", workload=spec.workload,
                  system=spec.system, backend=spec.backend):
            result = backend.evaluate(trace, arch, spec, ctx=self)
        self.stats["backend_evals"] += 1
        self._results[spec] = result
        return result

    def baseline(self, workload: str, backend: str = "analytic",
                 policy: str = "serial",
                 row_reuse: bool = True,
                 engine: str = "columnar") -> EvalResult:
        """The paper's 1.0: the baseline system at its own design point,
        evaluated under the SAME backend/policy/row-reuse/engine mode as
        the results it scales."""
        return self.run(EvalSpec(workload=workload,
                                 system=self.baseline_system,
                                 backend=backend, policy=policy,
                                 row_reuse=row_reuse, engine=engine))

    def normalized(self, result: EvalResult) -> dict[str, float]:
        """Normalize one result to its workload's baseline (memoized — the
        baseline is evaluated once per workload, not once per point)."""
        return result.normalized(self.baseline(result.workload,
                                               backend=result.spec.backend,
                                               policy=result.spec.policy,
                                               row_reuse=result.spec.row_reuse,
                                               engine=result.spec.engine))

    def sweep(self,
              workloads: str | Iterable[str] | None = None,
              systems: str | Iterable[str] | None = None,
              buffers: Sequence[tuple[int | None, int | None]] | None = None,
              backend: str = "analytic",
              policy: str = "serial",
              row_reuse: bool = True,
              engine: str = "columnar",
              plan: str = "default",
              verify: bool = False,
              workers: int = 1,
              csv_path: str | None = None,
              verbose: bool = False) -> list[EvalResult]:
        """Evaluate the cross product workloads × systems × buffer points.

        ``None`` axes default to every registered workload / system / the
        per-system default buffer point.  Returns results in grid order.
        ``workers > 1`` farms not-yet-cached points out to a process pool
        (:func:`concurrent.futures.ProcessPoolExecutor`), merges the
        returned results and build stats back into this Experiment's memo
        caches, and still returns deterministic grid order; ``workers <=
        1`` (the default) runs serially in-process.  ``csv_path``
        additionally persists the results (with normalized PPA columns) as
        a CSV artifact via
        :func:`repro.experiment.artifacts.write_results_csv`, so figures
        regenerate without re-running the sweep — plus a per-phase profile
        report (``<csv>.profile.json``, see :mod:`repro.obs.profile`)
        carrying the sweep's cache-stats delta.  ``verbose=True`` logs one
        structured line per grid point to stderr (spec fields, cache
        hit/miss, elapsed seconds) as the sweep progresses — on the
        parallel path workers time each point and the parent prints a
        ``[sweep pool]`` line as each chunk's progress arrives.
        ``verify=True`` (burst-sim points only) runs the
        :mod:`repro.check` schedule verifier after every replay — see
        :class:`~repro.experiment.backends.EvalSpec`.
        """
        if workloads is None:
            workloads = self.workloads.names()
        elif isinstance(workloads, str):
            workloads = (workloads,)
        if systems is None:
            systems = self.systems.names()
        elif isinstance(systems, str):
            systems = (systems,)
        points = buffers if buffers is not None else ((None, None),)
        specs = [EvalSpec(workload=w, system=s, gbuf_bytes=g,
                          lbuf_bytes=lb, backend=backend,
                          policy=policy, row_reuse=row_reuse,
                          engine=engine, plan=plan, verify=verify)
                 for w in workloads for s in systems for g, lb in points]
        baselines = [EvalSpec(workload=w, system=self.baseline_system,
                              backend=backend, policy=policy,
                              row_reuse=row_reuse, engine=engine)
                     for w in workloads] if csv_path is not None else []
        # profile the sweep: an already-active profiler (the caller's
        # ``with profiled():``) is reused; otherwise a csv_path sweep
        # activates its own so the report artifact is never empty
        stats_before = dict(self.stats)
        prof = active_profiler()
        scope = profiled() if csv_path is not None and prof is None \
            else contextlib.nullcontext(prof)
        with scope as prof:
            with span("experiment.sweep", points=len(specs),
                      workers=workers):
                results = self._dispatch(specs, workers, baselines,
                                         verbose=verbose)
        if csv_path is not None:
            from repro.experiment.artifacts import write_results_csv
            write_results_csv(csv_path, results, experiment=self)
            if prof is not None:
                delta = {k: v - stats_before.get(k, 0)
                         for k, v in self.stats.items()
                         if v != stats_before.get(k, 0)}
                prof.write_report(
                    Path(csv_path).with_suffix(".profile.json"),
                    meta={"points": len(specs), "workers": workers,
                          "stats_delta": delta})
        return results

    def _dispatch(self, specs: Sequence[EvalSpec], workers: int,
                  baselines: Sequence[EvalSpec] = (),
                  verbose: bool = False) -> list[EvalResult]:
        """Evaluate specs in order: one pool pass over the whole batch
        when ``workers > 1`` (plus the ``baselines`` a CSV's normalized
        columns will need — evaluated on the pool rather than serially in
        the parent afterwards), then serve everything from the memo."""
        if workers > 1:
            self._run_parallel(list(specs) + list(baselines), workers,
                               verbose=verbose)
        if not verbose:
            return [self.run(spec) for spec in specs]
        results = []
        for k, spec in enumerate(specs):
            resolved = self.resolve(spec)
            cached = resolved in self._results
            t = time.perf_counter()
            results.append(self.run(resolved))
            elapsed = time.perf_counter() - t
            print(f"[sweep {k + 1}/{len(specs)}] "
                  f"workload={resolved.workload} system={resolved.system} "
                  f"gbuf={resolved.gbuf_bytes} lbuf={resolved.lbuf_bytes} "
                  f"plan={resolved.plan} policy={resolved.policy} "
                  f"backend={resolved.backend} "
                  f"cached={'yes' if cached else 'no'} "
                  f"elapsed_s={elapsed:.3f}", file=_sys.stderr)
        return results

    def _shippable(self, specs: Sequence[EvalSpec]) -> dict[str, Any] | None:
        """The worker-job template for ``specs`` — pinned plan-override
        records plus a collector prototype — or ``None`` when the points
        cannot be reconstructed in a spawn worker (genuinely custom
        registries, a non-folding collector) and the sweep must stay
        serial.

        Pinned ``plan_overrides`` no longer force the serial path: a
        registry entry that equals the module-level registration modulo
        its overrides ships as :func:`repro.plan.artifacts
        .override_records` and is re-pinned inside each worker."""
        if self.backends is not BACKENDS:
            return None
        collector = self.collector
        if collector is not None and not (hasattr(collector, "fork")
                                          and hasattr(collector, "merge")):
            # a plain collector's replay-order event stream cannot be
            # folded back from a pool; keep replay observable serially
            return None
        for w in {spec.workload for spec in specs}:
            if w not in WORKLOADS or self.workloads.get(w) \
                    is not WORKLOADS.get(w):
                return None
        overrides: list[dict] = []
        from repro.plan.artifacts import override_records
        for s in sorted({spec.system for spec in specs}):
            if s not in SYSTEMS:
                return None
            mine = self.systems.get(s)
            if mine is not SYSTEMS.get(s):
                stripped = dataclasses.replace(mine, plan_overrides=())
                module = dataclasses.replace(SYSTEMS.get(s),
                                             plan_overrides=())
                if stripped != module:
                    return None
            if mine.plan_overrides:
                overrides.extend(override_records(self.systems, names=(s,)))
        return {"overrides": overrides, "collector": collector}

    def _run_parallel(self, specs: Sequence[EvalSpec], workers: int,
                      verbose: bool = False) -> None:
        """Evaluate not-yet-cached specs on a process pool and merge the
        results (plus the workers' build stats, folded collector state and
        per-point progress) into this Experiment.

        Workers rebuild their own Experiment over the MODULE-LEVEL
        registries — re-pinning any shipped plan overrides, re-attaching a
        fork of a :class:`~repro.obs.trace.FoldingCollector`, and reading
        the on-disk cache from the environment — so only genuinely custom
        registries (or a non-folding collector) fall back to the serial
        path.  Points are chunked by fully-resolved grid point —
        (workload, system, gbuf, lbuf, row-reuse, plan) — the unit that
        actually shares a mapped trace and burst lowering across its specs
        (policies / backends); distinct buffer points share nothing, so
        they parallelize freely even within one system.
        """
        job_template = self._shippable(specs)
        if job_template is None:
            return
        seen: set[EvalSpec] = set()
        chunks: dict[tuple, list[EvalSpec]] = {}
        for spec in specs:
            spec = self.resolve(spec)
            if spec in self._results or spec in seen:
                continue
            seen.add(spec)
            chunks.setdefault(
                (spec.workload, spec.system, spec.gbuf_bytes,
                 spec.lbuf_bytes, spec.row_reuse, spec.plan),
                []).append(spec)
        if not chunks:
            return
        collector = job_template.pop("collector")
        jobs = [dict(job_template, specs=chunk,
                     collector=None if collector is None
                     else collector.fork())
                for chunk in chunks.values()]
        self.stats["parallel_chunks"] += len(jobs)
        self.stats["parallel_points"] += len(seen)
        import concurrent.futures
        import multiprocessing
        import os
        import sys
        # spawn, not fork: the surrounding process may hold JAX (or other
        # multithreaded) state that a forked child would deadlock on; the
        # worker only needs the importable module-level registries anyway.
        # Spawn re-executes __main__.__file__ in each worker — mask it
        # when it is a pseudo-file (stdin / REPL pipes), which cannot be
        # re-run and is not needed: _sweep_worker lives in this module.
        main = sys.modules.get("__main__")
        main_file = getattr(main, "__file__", None)
        masked = main_file is not None and not os.path.exists(main_file)
        if masked:
            del main.__file__
        done, total = 0, len(seen)
        try:
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=workers,
                    mp_context=multiprocessing.get_context("spawn")) as pool:
                futures = [pool.submit(_sweep_worker, job) for job in jobs]
                for fut in concurrent.futures.as_completed(futures):
                    payload = fut.result()
                    for r in payload["results"]:
                        self._results.setdefault(r.spec, r)
                    for key, count in payload["stats"].items():
                        self.stats[key] = self.stats.get(key, 0) + count
                    if collector is not None \
                            and payload["collector"] is not None:
                        collector.merge(payload["collector"])
                    for spec, elapsed in payload["progress"]:
                        done += 1
                        if verbose:
                            print(
                                f"[sweep pool {done}/{total}] "
                                f"workload={spec.workload} "
                                f"system={spec.system} "
                                f"gbuf={spec.gbuf_bytes} "
                                f"lbuf={spec.lbuf_bytes} "
                                f"plan={spec.plan} policy={spec.policy} "
                                f"backend={spec.backend} "
                                f"elapsed_s={elapsed:.3f}",
                                file=_sys.stderr)
        finally:
            if masked:
                main.__file__ = main_file

    def pareto_frontier(self,
                        workload: str,
                        systems: str | Iterable[str] | None = None,
                        gbufs: Sequence[int | None] = (None,),
                        lbufs: Sequence[int | None] = (None,),
                        backend: str = "burst-sim",
                        policy: str | Sequence[str] = "row-aware",
                        row_reuse: bool | Sequence[bool] = True,
                        engine: str = "columnar",
                        plan: str | Sequence[str] = "default",
                        workers: int = 1,
                        csv_path: str | None = None) -> list[ParetoPoint]:
        """Sweep the (GBUF, LBUF, system) design grid for one workload and
        tag each point as Pareto-dominated or not over the PPA triple
        (cycles, energy, area) — the frontier the paper's buffer-sizing
        argument walks.  ``policy`` / ``row_reuse`` / ``plan`` also accept
        sequences, extending the grid along the issue-policy, row-reuse
        and fusion-plan axes (dominance is tagged across the WHOLE grid,
        so e.g. a searched plan can knock a greedy point off the
        frontier).  Returns every grid point in sweep order (filter on
        ``dominated`` for the frontier); ``csv_path`` persists the tagged
        grid via :func:`repro.experiment.artifacts.write_pareto_csv`.

        The plan axis only emits plan values that RESOLVE to distinct
        partitions at each (system, buffer) point (a layer-by-layer
        system ignores the knob entirely; on fused systems e.g.
        ``"default"`` with no pinned override ≡ ``"greedy"``, and the
        searched optimum sometimes IS the greedy plan) — otherwise the
        grid would carry physically identical duplicate points, each
        shielding the other from dominance (ties dominate nothing)."""
        policies = (policy,) if isinstance(policy, str) else tuple(policy)
        modes = (row_reuse,) if isinstance(row_reuse, bool) \
            else tuple(row_reuse)
        plans = (plan,) if isinstance(plan, str) else tuple(plan)
        if systems is None:
            systems = self.systems.names()
        elif isinstance(systems, str):
            systems = (systems,)
        # plan values deduped by the partition they resolve to, per
        # (system, resolved buffer point); plan resolution is independent
        # of policy/row-reuse, so this is computed once per point
        combos: list[tuple[str, int | None, int | None, str]] = []
        seen: set[tuple] = set()
        for s in systems:
            sys_spec = self.systems.get(s)
            g0, l0 = sys_spec.default_buffers
            for g in gbufs:
                for lb in lbufs:
                    rg = g0 if g is None else g
                    rl = l0 if lb is None else lb
                    for pl in plans:
                        sig = None if sys_spec.tile_grid is None else \
                            self.plan(workload, sys_spec.tile_grid,
                                      system=s, source=pl, gbuf_bytes=rg,
                                      lbuf_bytes=rl).signature()
                        key = (s, rg, rl, sig)
                        if key in seen:
                            continue
                        seen.add(key)
                        combos.append((s, g, lb, pl))
        specs = [EvalSpec(workload=workload, system=s, gbuf_bytes=g,
                          lbuf_bytes=lb, backend=backend, policy=pol,
                          row_reuse=rr, engine=engine, plan=pl)
                 for pol in policies for rr in modes
                 for s, g, lb, pl in combos]
        # ONE pool pass over the whole extended grid: specs differing
        # only in policy chunk onto the same worker (shared trace +
        # lowering), instead of a fresh pool per axis combo
        baselines = [EvalSpec(workload=workload,
                              system=self.baseline_system,
                              backend=backend, policy=pol, row_reuse=rr,
                              engine=engine)
                     for pol in policies for rr in modes] \
            if csv_path is not None else []
        results = self._dispatch(specs, workers, baselines)
        points = [ParetoPoint(result=r, dominated=d)
                  for r, d in zip(results, pareto_tags(results))]
        if csv_path is not None:
            from repro.experiment.artifacts import write_pareto_csv
            write_pareto_csv(csv_path, points, experiment=self)
        return points


# ---------------------------------------------------------------------------
# process-wide default (shared cache behind the legacy pim.ppa shims)
# ---------------------------------------------------------------------------

_DEFAULT: Experiment | None = None


def default_experiment() -> Experiment:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Experiment()
    return _DEFAULT
