"""Named registries for the experiment layer: workloads, systems, backends.

The paper's evaluation is a grid — {systems} × {workloads} × (GBUF, LBUF)
× {evaluation backend} — and every axis here is a small named registry so
new entries compose with the whole grid without touching any driver code:

* a **workload** is a zero-arg builder returning a
  :class:`repro.core.graph.Graph` (register with :func:`register_workload`),
* a **system** bundles the arch factory, the fused-dataflow tile grid, and
  the paper's default (GBUF, LBUF) design point
  (:class:`SystemSpec` / :func:`register_system`),
* a **backend** maps a mapped trace to a result
  (see :mod:`repro.experiment.backends`).

Registries preserve registration order (the canonical reporting order) and
raise `KeyError` naming the known entries on unknown lookups.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Generic, Iterator, TypeVar

from repro.core.fusion import PlanSig
from repro.core.graph import Graph
from repro.pim.arch import PIMArch

T = TypeVar("T")


class Registry(Generic[T]):
    """Ordered name → item mapping with helpful unknown-name errors."""

    def __init__(self, kind: str) -> None:
        self.kind = kind
        self._items: dict[str, T] = {}

    def register(self, name: str, item: T, *, replace: bool = False) -> T:
        if not replace and name in self._items:
            raise ValueError(
                f"{self.kind} '{name}' already registered "
                f"(pass replace=True to override)")
        self._items[name] = item
        return item

    def get(self, name: str) -> T:
        try:
            return self._items[name]
        except KeyError:
            known = ", ".join(self._items) or "<none>"
            raise KeyError(f"unknown {self.kind} '{name}' "
                           f"(registered: {known})") from None

    def names(self) -> tuple[str, ...]:
        return tuple(self._items)

    def clone(self) -> "Registry[T]":
        """A shallow copy: same entries, independent mutation — the way to
        pin per-workload plan overrides without touching the process-wide
        registry (pass the clone to ``Experiment(systems=...)``)."""
        out: Registry[T] = Registry(self.kind)
        out._items = dict(self._items)
        return out

    def items(self) -> Iterator[tuple[str, T]]:
        return iter(self._items.items())

    def __contains__(self, name: object) -> bool:
        return name in self._items

    def __iter__(self) -> Iterator[str]:
        return iter(self._items)

    def __len__(self) -> int:
        return len(self._items)


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A named CNN workload: a zero-arg :class:`Graph` builder."""

    name: str
    builder: Callable[[], Graph]
    description: str = ""

    def build(self) -> Graph:
        return self.builder()


WORKLOADS: Registry[WorkloadSpec] = Registry("workload")


def register_workload(name: str, *, description: str = "",
                      registry: Registry[WorkloadSpec] = WORKLOADS,
                      replace: bool = False) -> "Callable[[Callable[[], Graph]], Callable[[], Graph]]":
    """Decorator registering a ``() -> Graph`` builder as a named workload.

    >>> @register_workload("TinyNet", description="3-layer smoke net")
    ... def _tiny() -> Graph: ...
    """

    def deco(builder: Callable[[], Graph]) -> Callable[[], Graph]:
        registry.register(name, WorkloadSpec(name, builder, description),
                          replace=replace)
        return builder

    return deco


# ---------------------------------------------------------------------------
# Systems
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """One evaluated PIM system: arch factory + dataflow + design point.

    ``tile_grid is None`` selects the layer-by-layer baseline dataflow;
    otherwise the fused-layer dataflow runs with that (tiles_y, tiles_x)
    grid (its tile count must equal the arch's PIMcore count).
    ``default_buffers`` is the system's headline (gbuf_bytes, lbuf_bytes)
    design point (§V-3 / §V-D), used when an EvalSpec leaves them unset.
    ``plan_overrides`` pins a fusion-plan signature per workload name
    (:data:`repro.core.fusion.PlanSig`, as (workload, signature) pairs):
    when present, the ``"default"`` plan source maps that workload with
    the pinned partition instead of the greedy rule — how a searched plan
    (``Experiment.search_plan`` / ``pin_plan``) is reproduced exactly.
    """

    name: str
    arch_factory: Callable[..., PIMArch]
    tile_grid: tuple[int, int] | None = None
    default_buffers: tuple[int, int] = (2 * 1024, 0)
    description: str = ""
    plan_overrides: tuple[tuple[str, PlanSig], ...] = ()

    def make_arch(self, gbuf_bytes: int | None = None,
                  lbuf_bytes: int | None = None) -> PIMArch:
        g0, l0 = self.default_buffers
        return self.arch_factory(
            gbuf_bytes=g0 if gbuf_bytes is None else gbuf_bytes,
            lbuf_bytes=l0 if lbuf_bytes is None else lbuf_bytes)

    def plan_override(self, workload: str) -> PlanSig | None:
        """The pinned plan signature for ``workload``, if any."""
        for name, sig in self.plan_overrides:
            if name == workload:
                return sig
        return None

    def with_plan_override(self, workload: str,
                           sig: PlanSig | None) -> "SystemSpec":
        """A copy of this spec with ``workload``'s plan pinned to ``sig``
        (``None`` unpins).  The tile grid of every group must match the
        system's grid — an override cannot smuggle in a different grid."""
        if sig is not None:
            for start, stop, ty, tx in sig[0]:
                if (ty, tx) != self.tile_grid:
                    raise ValueError(
                        f"override group [{start}:{stop}) grid {ty}x{tx} "
                        f"!= system {self.name} grid {self.tile_grid}")
        kept = tuple((w, s) for w, s in self.plan_overrides
                     if w != workload)
        if sig is not None:
            kept += ((workload, sig),)
        return dataclasses.replace(self, plan_overrides=kept)


SYSTEMS: Registry[SystemSpec] = Registry("system")


def register_system(spec: SystemSpec, *,
                    registry: Registry[SystemSpec] = SYSTEMS,
                    replace: bool = False) -> SystemSpec:
    return registry.register(spec.name, spec, replace=replace)
