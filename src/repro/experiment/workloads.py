"""Built-in workload registrations.

The paper's two ResNet18 benchmarks (§V-2) plus two structurally different
CNNs proving the registry extends beyond the paper: a residual-free VGG
chain and a depthwise-separable MobileNet (grouped convs).  All are plain
``() -> Graph`` builders; register more with
:func:`repro.experiment.register_workload`.
"""

from __future__ import annotations

from repro.core.graph import (Graph, build_mobilenet_v1, build_resnet18,
                              build_vgg11, first_n_layers)
from repro.experiment.registry import register_workload


@register_workload("ResNet18_First8Layers",
                   description="ResNet18 stem + stage 1 (paper §V-2, the "
                               "fusion-dominated slice)")
def _resnet18_first8() -> Graph:
    return first_n_layers(build_resnet18(), 8)


@register_workload("ResNet18_Full",
                   description="Full ResNet18 @224 (paper §V-2)")
def _resnet18_full() -> Graph:
    return build_resnet18()


@register_workload("VGG11",
                   description="VGG11 @224: residual-free conv/pool chain "
                               "+ 3-layer FC head")
def _vgg11() -> Graph:
    return build_vgg11()


@register_workload("MobileNetV1",
                   description="MobileNetV1 @224: depthwise-separable "
                               "blocks (grouped convs)")
def _mobilenet_v1() -> Graph:
    return build_mobilenet_v1()
