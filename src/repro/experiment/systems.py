"""Built-in system registrations: the paper's three evaluated systems.

Single source of truth for arch factory + tile grid + headline buffer
point; `repro.pim.ppa`'s legacy ``SYSTEMS`` / ``TILE_GRID`` /
``HEADLINE_CONFIGS`` constants are derived views of this registry.
"""

from __future__ import annotations

from repro.experiment.registry import SystemSpec, register_system
from repro.pim import arch as pim_arch

# the paper's 1.0: AiM-like at its own design point (G2K_L0)
BASELINE_SYSTEM = "AiM-like"

register_system(SystemSpec(
    name="AiM-like",
    arch_factory=pim_arch.aim_like,
    tile_grid=None,                      # layer-by-layer dataflow (Fig. 3b)
    default_buffers=(2 * 1024, 0),       # AiM design point (G2K_L0)
    description="GDDR6-AiM-like baseline: 16 1-bank PIMcores + GBcore, "
                "layer-by-layer dataflow"))

register_system(SystemSpec(
    name="Fused16",
    arch_factory=pim_arch.fused16,
    tile_grid=(4, 4),                    # 16 tiles = 16 PIMcores (§V-3)
    default_buffers=(32 * 1024, 256),    # paper's §V-D G32K_L256 point
    description="PIMfused, 16 1-bank PIMcores, 4x4 fused tile grid"))

register_system(SystemSpec(
    name="Fused4",
    arch_factory=pim_arch.fused4,
    tile_grid=(2, 2),                    # 4 tiles = 4 PIMcores (§V-3)
    default_buffers=(32 * 1024, 256),
    description="PIMfused, 4 4-bank PIMcores, 2x2 fused tile grid"))
