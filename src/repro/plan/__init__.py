"""`repro.plan` — fusion-partition search: DP + beam autotuner.

Turns the fused-layer partition from a hard-coded greedy rule
(:func:`repro.core.fusion.plan_fused`) into a searched decision:

* :mod:`repro.plan.space` — the legal plan space, enumerated through the
  same :func:`~repro.core.fusion.is_legal_group` checks the greedy obeys
  (so greedy plans are always inside it).
* :mod:`repro.plan.dp` — exact split-point DP over layer boundaries; the
  analytic cost decomposes additively over groups / boundary reorgs /
  tail, so the optimum is found in O(boundaries²) group evaluations and
  is ≤ the greedy plan's cost by construction.
* :mod:`repro.plan.beam` — beam search over the joint (partition × tile
  grid × GBUF/LBUF) space when the DP's single-combo axis is too narrow.
* :mod:`repro.plan.artifacts` — JSON persistence for searched plans
  (pin them via ``SystemSpec`` per-workload overrides).

Driver entry points: ``Experiment.search_plan()`` / ``Experiment.pin_plan()``
and the ``EvalSpec.plan`` knob (``"default"`` / ``"greedy"`` /
``"searched"``); see ``benchmarks/plan_search.py`` for the searched-vs-
greedy comparison including a burst-sim spot check.

A scientific note (measured, see README "How the fusion split is chosen"):
on this reproduction's calibrated cost model the DP does NOT rediscover
the paper's hand-derived ResNet18 splits — it finds strictly cheaper
partitions (the hand splits are in the search space and are beaten), both
under the analytic model and under burst-sim replay.  The greedy rule
therefore remains the default plan source everywhere; searched plans are
an opt-in axis.

Caveat under fault injection: the search costs plans on HEALTHY
hardware.  An ``EvalSpec`` with structural faults replays the
fault-free-optimal plan through the degraded remapping
(:mod:`repro.faults.remap`) — it does not re-partition around dead
banks/cores, so a searched plan's win can shrink (or invert) as banks
die.  ``benchmarks/degradation_report.py`` measures exactly that slope;
fault-aware re-planning is an open item (ROADMAP).
"""

from repro.core.fusion import (RECOVERABLE_CODES, group_legality,
                               group_legality_coded, is_legal_group)
from repro.plan.artifacts import (SCHEMA, load_plan, plan_record,
                                  read_plan_json, write_plan_json)
from repro.plan.beam import BeamCandidate, beam_search
from repro.plan.dp import (PlanCost, SearchResult, analytic_cycles,
                           analytic_energy, search_partition)
from repro.plan.space import (candidate_grids, count_partitions,
                              enumerate_partitions, legal_stops)

__all__ = [
    "RECOVERABLE_CODES", "SCHEMA", "BeamCandidate", "PlanCost",
    "SearchResult", "analytic_cycles", "analytic_energy", "beam_search",
    "candidate_grids", "count_partitions", "enumerate_partitions",
    "group_legality", "group_legality_coded", "is_legal_group",
    "legal_stops", "load_plan", "plan_record", "read_plan_json",
    "search_partition", "write_plan_json",
]
