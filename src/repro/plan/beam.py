"""Beam search over the joint (partition × tile grid × GBUF/LBUF) space.

The DP (:mod:`repro.plan.dp`) is exact along the partition axis but fixes
the tile grid and the buffer point.  The autotuner's outer axes — which
grid factorization of the core count, which (GBUF, LBUF) design point —
multiply the space; the beam explores all of it in one frontier:

* a **state** is a partial partition of one (grid, buffers) combo — the
  position reached, the groups chosen so far, and the accumulated cost;
* expansion either *closes* the state into the layer-by-layer tail
  (a finished plan) or appends any legal fused group;
* pruning keeps the globally best ``beam_width`` open states ranked by
  ``accumulated + close(position)`` — a *feasible* completion (finish
  layer-by-layer now), so states from different combos and different
  depths are compared on an achievable total, never an underestimate.

With a wide enough beam the search is exhaustive and matches the DP on
every combo (a property the tests pin); narrow beams trade optimality for
a bounded number of group evaluations.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.fusion import FusedGroup, FusionPlan
from repro.core.graph import Graph
from repro.obs.profile import span
from repro.plan.dp import PlanCost, TraceCost
from repro.plan.space import candidate_grids

__all__ = ["BeamCandidate", "beam_search"]


@dataclasses.dataclass(frozen=True)
class BeamCandidate:
    """One finished plan of the joint search, cheapest first."""

    plan: FusionPlan
    cost: float
    tile_grid: tuple[int, int]
    gbuf_bytes: int
    lbuf_bytes: int


def beam_search(graph: Graph, arch_factory: Callable[[int, int], PIMArch], *,
                buffers: Sequence[tuple[int, int]],
                grids: Sequence[tuple[int, int]] | None = None,
                beam_width: int = 8, keep: int = 5,
                trace_cost: TraceCost | None = None,
                min_group_len: int = 2,
                stage_aligned: bool = True) -> list[BeamCandidate]:
    """Search plans jointly over grids × buffer points × partitions.

    ``arch_factory`` is a :class:`~repro.experiment.registry.SystemSpec`
    style factory (``arch_factory(gbuf_bytes=…, lbuf_bytes=…)``); ``grids``
    defaults to every factorization of the arch's PIMcore count.  Returns
    up to ``keep`` finished candidates sorted by cost — note costs across
    buffer points share the objective but not the hardware, so the caller
    decides whether the comparison is fair (e.g. add an area term, or pass
    a single buffer point to tune the grid alone).
    """
    combos: list[tuple[PlanCost, int, int]] = []
    for g, lb in buffers:
        arch = arch_factory(gbuf_bytes=g, lbuf_bytes=lb)
        for ty, tx in (grids or candidate_grids(arch.num_pimcores)):
            if ty * tx != arch.num_pimcores:
                raise ValueError(
                    f"grid {ty}x{tx} = {ty * tx} tiles != "
                    f"{arch.num_pimcores} PIMcores of {arch.name}")
            combos.append((PlanCost(graph, arch, ty, tx,
                                    trace_cost=trace_cost,
                                    min_group_len=min_group_len,
                                    stage_aligned=stage_aligned), g, lb))

    # state: (combo index, position, groups so far, accumulated cost)
    State = tuple[int, int, tuple[tuple[int, int], ...], float]
    open_states: list[State] = [(ci, 0, (), 0.0)
                                for ci in range(len(combos))]
    finished: list[tuple[float, int, tuple[tuple[int, int], ...], int]] = []
    with span("plan.beam", combos=len(combos), beam_width=beam_width):
        while open_states:
            nxt: list[State] = []
            for ci, pos, groups, acc in open_states:
                cost = combos[ci][0]
                finished.append((acc + cost.close(pos), ci, groups, pos))
                for stop in cost.stops(pos):
                    step = (cost.reorg(pos, (pos, stop))
                            if pos > 0 else 0.0) + cost.group(pos, stop)
                    nxt.append((ci, stop, groups + ((pos, stop),),
                                acc + step))
            nxt.sort(key=lambda s: s[3] + combos[s[0]][0].close(s[1]))
            open_states = nxt[:beam_width]

    finished.sort(key=lambda f: f[0])
    out: list[BeamCandidate] = []
    for total, ci, groups, tail in finished[:keep]:
        cost, g, lb = combos[ci]
        plan = FusionPlan(
            graph=graph,
            groups=tuple(FusedGroup(a, b, cost.tiles_y, cost.tiles_x)
                         for a, b in groups),
            tail_start=tail)
        out.append(BeamCandidate(plan=plan, cost=total,
                                 tile_grid=(cost.tiles_y, cost.tiles_x),
                                 gbuf_bytes=g, lbuf_bytes=lb))
    return out
