"""The fusion-partition search space: what plans are LEGAL to consider.

A plan (matching :class:`repro.core.fusion.FusionPlan`'s representational
capacity and what :func:`repro.core.dataflow.map_pimfused` executes) is a
sequence of fused groups covering a contiguous prefix ``[0, tail_start)``
of the graph, followed by a layer-by-layer tail.  Every group must pass
:func:`repro.core.fusion.is_legal_group` — the same residual-crossing /
tile-divisibility / extent rules the greedy planner applies, so greedy
plans are always points of this space and a cost-optimal search can never
do worse than the greedy rule.

This module only enumerates; costs live in :mod:`repro.plan.dp`.
"""

from __future__ import annotations

from typing import Iterator

from repro.core.fusion import (RECOVERABLE_CODES, FusedGroup, FusionPlan,
                               group_legality_coded)
from repro.core.graph import Graph

__all__ = ["legal_stops", "enumerate_partitions", "count_partitions",
           "candidate_grids"]


def legal_stops(graph: Graph, start: int, tiles_y: int, tiles_x: int, *,
                min_group_len: int = 2,
                stage_aligned: bool = True) -> list[int]:
    """All ``stop`` positions such that [start, stop) is a legal fused
    group — the branching factor of the split-point DP at ``start``.

    Scans ascending and stops early once a group becomes irrecoverably
    illegal (an unfusable layer entered the candidate range): every
    failure code outside :data:`repro.core.fusion.RECOVERABLE_CODES` is
    prefix-monotone, so no larger stop can become legal again.
    """
    stops: list[int] = []
    for stop in range(start + min_group_len, len(graph) + 1):
        coded = group_legality_coded(graph, start, stop, tiles_y, tiles_x,
                                     min_group_len=min_group_len,
                                     stage_aligned=stage_aligned)
        if coded is None:
            stops.append(stop)
        elif coded[0] not in RECOVERABLE_CODES:
            break
    return stops


def enumerate_partitions(graph: Graph, tiles_y: int, tiles_x: int, *,
                         min_group_len: int = 2, stage_aligned: bool = True,
                         max_plans: int | None = None,
                         ) -> Iterator[FusionPlan]:
    """Every legal plan: contiguous fused groups from layer 0 + tail.

    Includes the all-tail plan (no fused group: ``map_pimfused`` then
    degrades to pure layer-by-layer) and every greedy plan.  Exponential in
    the number of legal split points — use for exhaustive validation on
    real CNNs (ResNet18 has ~10² legal plans per grid) and small property
    graphs; ``max_plans`` guards runaway spaces.
    """
    stops_from: dict[int, list[int]] = {}
    emitted = 0

    def stops(i: int) -> list[int]:
        s = stops_from.get(i)
        if s is None:
            s = stops_from[i] = legal_stops(graph, i, tiles_y, tiles_x,
                                            min_group_len=min_group_len,
                                            stage_aligned=stage_aligned)
        return s

    def rec(i: int, acc: list[FusedGroup]) -> Iterator[FusionPlan]:
        nonlocal emitted
        if max_plans is not None and emitted >= max_plans:
            return
        emitted += 1
        yield FusionPlan(graph=graph, groups=tuple(acc), tail_start=i)
        for stop in stops(i):
            acc.append(FusedGroup(i, stop, tiles_y, tiles_x))
            yield from rec(stop, acc)
            acc.pop()

    yield from rec(0, [])


def count_partitions(graph: Graph, tiles_y: int, tiles_x: int, *,
                     min_group_len: int = 2,
                     stage_aligned: bool = True) -> int:
    """Size of the legal plan space (cheap: DP over split points)."""
    n = len(graph)
    counts = [0] * (n + 1)
    for i in range(n, -1, -1):
        counts[i] = 1  # close to tail here
        for stop in legal_stops(graph, i, tiles_y, tiles_x,
                                min_group_len=min_group_len,
                                stage_aligned=stage_aligned):
            counts[i] += counts[stop]
    return counts[0]


def candidate_grids(num_tiles: int) -> tuple[tuple[int, int], ...]:
    """All (tiles_y, tiles_x) factorizations of a PIMcore count — the tile
    count must equal the core count (§V-3), so these are the only grids a
    system with ``num_tiles`` cores can run.  Squarest first (smallest
    aspect ratio ⇒ smallest halo perimeter), which is the natural visit
    order for the beam."""
    grids = [(ty, num_tiles // ty) for ty in range(1, num_tiles + 1)
             if num_tiles % ty == 0]
    return tuple(sorted(grids, key=lambda g: (abs(g[0] - g[1]), g[0])))
