"""Optimal split-point DP over fused-layer partitions.

The total cost of a plan under the analytic backend decomposes exactly —
``map_pimfused`` concatenates per-group traces, boundary reorganisations
and the layer-by-layer tail, and both ``simulate_cycles`` and
``simulate_energy`` are plain sums over commands — so a partition's cost
has optimal substructure over split points:

    cost(plan) =   Σ_groups  group(g)
                 + Σ_bounds  reorg(boundary → next group / tail)
                 + tail(tail_start)

:class:`PlanCost` memoizes each term (per-group traces are the expensive
part; the per-layer tail costs are suffix sums computed once), and
:func:`search_partition` runs the DP backwards over layer positions.  Any
ADDITIVE trace cost works (cycles by default, energy via
:func:`analytic_energy`); non-additive objectives (burst-sim makespan
under overlapping issue policies) cannot ride the DP — rescore candidate
plans through the simulator instead (see ``benchmarks/plan_search.py``).

Because every greedy plan is a point of the legal space
(:mod:`repro.plan.space`), the DP optimum is ≤ the greedy plan's cost by
construction — the guarantee the acceptance tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import dataflow
from repro.core.fusion import FusedGroup, FusionPlan, plan_fused
from repro.core.graph import Graph
from repro.core.tiling import GroupTiling, tile_group
from repro.obs.profile import span
from repro.pim.arch import PIMArch
from repro.plan.space import legal_stops

__all__ = ["PlanCost", "SearchResult", "analytic_cycles", "analytic_energy",
           "search_partition"]

# A trace cost must be ADDITIVE over trace concatenation for the DP's
# decomposition to equal the full-plan cost (both built-ins are).
TraceCost = Callable[[list, PIMArch], float]


def analytic_cycles(trace: list, arch: PIMArch) -> float:
    """Default objective: the analytic memory-system cycle total (what the
    paper's figures report and what the serial burst replay reproduces to
    the cycle)."""
    from repro.pim.timing import simulate_cycles
    return simulate_cycles(trace, arch).total


def analytic_energy(trace: list, arch: PIMArch) -> float:
    """Alternative objective: analytic energy in nJ (also additive)."""
    from repro.pim.energy import simulate_energy
    return simulate_energy(trace, arch).total_nj


class PlanCost:
    """Memoized additive cost terms of fusion plans on one (arch, grid).

    One instance per (graph, arch, tile grid, objective); the DP, the beam
    and plan rescoring all pull from the same caches, so a candidate group
    is tiled/mapped/priced at most once however many plans contain it.
    """

    def __init__(self, graph: Graph, arch: PIMArch, tiles_y: int,
                 tiles_x: int, *, trace_cost: TraceCost | None = None,
                 min_group_len: int = 2, stage_aligned: bool = True) -> None:
        if tiles_y * tiles_x != arch.num_pimcores:
            raise ValueError(
                f"tile grid {tiles_y}x{tiles_x} = {tiles_y * tiles_x} tiles "
                f"!= {arch.num_pimcores} PIMcores of {arch.name}")
        self.graph = graph
        self.arch = arch
        self.tiles_y = tiles_y
        self.tiles_x = tiles_x
        self.trace_cost = trace_cost or analytic_cycles
        self.min_group_len = min_group_len
        self.stage_aligned = stage_aligned
        self._tilings: dict[tuple[int, int], GroupTiling] = {}
        self._groups: dict[tuple[int, int], float] = {}
        self._halos: dict[tuple[int, int], int] = {}
        self._reorgs: dict[tuple[int, int | None], float] = {}
        self._stops: dict[int, list[int]] = {}
        # per-layer layer-by-layer costs (map_layer_by_layer emits commands
        # layer-independently, so the suffix sum IS the tail trace's cost)
        per_layer = [self.trace_cost(
            dataflow.map_layer_by_layer(graph, arch, start=i, stop=i + 1),
            arch) for i in range(len(graph))]
        self._tail = [0.0] * (len(graph) + 1)
        for i in range(len(graph) - 1, -1, -1):
            self._tail[i] = per_layer[i] + self._tail[i + 1]
        self.stats = {"group_costs": 0, "tilings": 0}

    # ------------------------------------------------------------------
    # memoized terms
    # ------------------------------------------------------------------

    def stops(self, start: int) -> list[int]:
        s = self._stops.get(start)
        if s is None:
            s = self._stops[start] = legal_stops(
                self.graph, start, self.tiles_y, self.tiles_x,
                min_group_len=self.min_group_len,
                stage_aligned=self.stage_aligned)
        return s

    def tiling(self, start: int, stop: int) -> GroupTiling:
        t = self._tilings.get((start, stop))
        if t is None:
            self.stats["tilings"] += 1
            t = self._tilings[(start, stop)] = tile_group(
                self.graph.slice(start, stop), self.tiles_y, self.tiles_x)
        return t

    def halo(self, start: int, stop: int) -> int:
        """The group's receptive-field input halo in bytes (what the reorg
        into this group moves, clamped by the mapper at one map pass)."""
        h = self._halos.get((start, stop))
        if h is None:
            h = self._halos[(start, stop)] = dataflow.group_input_halo_bytes(
                self.graph.slice(start, stop), self.tiling(start, stop),
                self.arch.dtype_bytes)
        return h

    def group(self, start: int, stop: int) -> float:
        """Cost of executing [start, stop) as one fused kernel."""
        c = self._groups.get((start, stop))
        if c is None:
            self.stats["group_costs"] += 1
            grp = FusedGroup(start, stop, self.tiles_y, self.tiles_x)
            trace = dataflow.map_fused_group(self.graph, grp, self.arch,
                                             tiling=self.tiling(start, stop))
            c = self._groups[(start, stop)] = self.trace_cost(trace,
                                                              self.arch)
        return c

    def reorg(self, boundary: int, next_group: tuple[int, int] | None
              ) -> float:
        """Boundary reorganisation after a group ending at ``boundary``:
        into the next fused group (moves its tiling halo) or into the tail
        (``next_group=None``, full-map redistribution).  Zero at the graph
        edges (nothing precedes layer 0 / follows layer n)."""
        if boundary <= 0 or boundary >= len(self.graph):
            return 0.0
        key = (boundary, next_group and next_group[1])
        c = self._reorgs.get(key)
        if c is None:
            halo = None if next_group is None else self.halo(*next_group)
            trace = dataflow.map_boundary_reorg(self.graph, boundary,
                                                self.arch, halo)
            c = self._reorgs[key] = self.trace_cost(trace, self.arch)
        return c

    def tail(self, start: int) -> float:
        """Layer-by-layer cost of the suffix [start, len)."""
        return self._tail[start]

    def close(self, boundary: int) -> float:
        """Cost of finishing layer-by-layer from ``boundary`` (reorg into
        the tail + the tail itself) — also the DP's feasible-completion
        bound the beam prunes by."""
        return self.reorg(boundary, None) + self.tail(boundary)

    # ------------------------------------------------------------------

    def plan_cost(self, plan: FusionPlan) -> float:
        """Score ANY plan by the same decomposition the DP optimizes —
        exactly equals ``trace_cost(map_pimfused(plan, arch), arch)``."""
        total = 0.0
        for gi, g in enumerate(plan.groups):
            if gi > 0:
                total += self.reorg(g.start, (g.start, g.stop))
            total += self.group(g.start, g.stop)
        if plan.tail_start < len(plan.graph):
            total += self.close(plan.tail_start)
        return total


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Outcome of one partition search on one (arch, grid) point."""

    plan: FusionPlan
    cost: float
    tile_grid: tuple[int, int]
    # the greedy rule's plan and cost under the SAME objective — None when
    # the grid admits no greedy plan at all (plan_fused raises)
    greedy_plan: FusionPlan | None
    greedy_cost: float | None
    evaluated_groups: int           # distinct fused groups priced

    @property
    def improvement(self) -> float:
        """Fractional cost reduction vs the greedy plan (0.0 when greedy
        is already optimal or does not exist)."""
        if self.greedy_cost is None or self.greedy_cost <= 0:
            return 0.0
        return 1.0 - self.cost / self.greedy_cost


def search_partition(graph: Graph, arch: PIMArch, tiles_y: int,
                     tiles_x: int, *, trace_cost: TraceCost | None = None,
                     min_group_len: int = 2, stage_aligned: bool = True,
                     cost: PlanCost | None = None) -> SearchResult:
    """Cost-optimal fusion partition by DP over split points.

    ``F[i]`` = cheapest way to execute ``[i, n)`` given a group boundary at
    ``i``; transitions either close into the layer-by-layer tail or open a
    legal fused group ``[i, j)``, paying the boundary reorganisation into
    it (charged at the transition, where both endpoints are known).
    """
    if cost is None:
        cost = PlanCost(graph, arch, tiles_y, tiles_x,
                        trace_cost=trace_cost, min_group_len=min_group_len,
                        stage_aligned=stage_aligned)
    n = len(graph)
    # F[i] = (cost, best stop j or None-for-tail), computed backwards
    best: list[tuple[float, int | None]] = [(0.0, None)] * (n + 1)
    with span("plan.dp", layers=n, grid=f"{tiles_y}x{tiles_x}"):
        for i in range(n - 1, -1, -1):
            c_best, choice = cost.close(i), None
            for j in cost.stops(i):
                c = (cost.reorg(i, (i, j)) if i > 0 else 0.0) \
                    + cost.group(i, j) + best[j][0]
                if c < c_best:
                    c_best, choice = c, j
            best[i] = (c_best, choice)

    groups: list[FusedGroup] = []
    i = 0
    while i < n and best[i][1] is not None:
        j = best[i][1]
        groups.append(FusedGroup(i, j, tiles_y, tiles_x))
        i = j
    plan = FusionPlan(graph=graph, groups=tuple(groups), tail_start=i)

    try:
        greedy = plan_fused(graph, tiles_y, tiles_x,
                            min_group_len=min_group_len,
                            stage_aligned=stage_aligned)
        greedy_cost = cost.plan_cost(greedy)
    except ValueError:
        greedy, greedy_cost = None, None
    return SearchResult(plan=plan, cost=best[0][0],
                        tile_grid=(tiles_y, tiles_x),
                        greedy_plan=greedy, greedy_cost=greedy_cost,
                        evaluated_groups=cost.stats["group_costs"])
