"""JSON artifacts for searched fusion plans.

Sits next to the CSV sweep artifacts (:mod:`repro.experiment.artifacts`):
a searched plan persists as one JSON file carrying the plan signature, the
search coordinates (workload, system, tile grid, buffer point), and the
searched-vs-greedy costs, so a plan can be audited, re-pinned via
``SystemSpec`` overrides, or replotted without re-running the search.

::

    sr = exp.search_plan("VGG11", "Fused16")
    path = write_plan_json("artifacts/plan_vgg11_fused16.json",
                           plan_record(sr, workload="VGG11",
                                       system="Fused16"))
    rec = read_plan_json(path)
    plan = load_plan(rec, exp.graph(rec["workload"]))   # legality re-checked
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import TYPE_CHECKING

from repro.core.fusion import FusionPlan, plan_from_dict
from repro.core.graph import Graph

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.plan.dp import SearchResult

SCHEMA = "repro.plan/1"
OVERRIDE_SCHEMA = "repro.plan-override/1"

__all__ = ["SCHEMA", "OVERRIDE_SCHEMA", "plan_record", "write_plan_json",
           "read_plan_json", "load_plan", "override_records",
           "apply_override_records"]


def plan_record(search: "SearchResult", *, workload: str, system: str,
                gbuf_bytes: int | None = None,
                lbuf_bytes: int | None = None,
                cost_metric: str = "analytic-cycles") -> dict:
    """Flatten one :class:`~repro.plan.dp.SearchResult` into the artifact
    schema (plan + search coordinates + searched/greedy costs)."""
    rec = {
        "schema": SCHEMA,
        "workload": workload,
        "system": system,
        "tile_grid": list(search.tile_grid),
        "gbuf_bytes": gbuf_bytes,
        "lbuf_bytes": lbuf_bytes,
        "cost_metric": cost_metric,
        "cost": search.cost,
        "greedy_cost": search.greedy_cost,
        "improvement": search.improvement,
        "describe": search.plan.describe(),
        **search.plan.to_dict(),
    }
    return rec


def write_plan_json(path: str | Path, record: dict) -> Path:
    """Persist a plan record (parent directories created)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=2, sort_keys=True) + "\n")
    return path


def read_plan_json(path: str | Path) -> dict:
    """Read a plan record back, checking the schema tag."""
    record = json.loads(Path(path).read_text())
    if record.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} artifact "
                         f"(schema={record.get('schema')!r})")
    return record


def load_plan(record: dict, graph: Graph, *,
              validate: bool = True) -> FusionPlan:
    """Rebuild the :class:`~repro.core.fusion.FusionPlan` of a record on
    ``graph`` — graph name/length and (by default) group legality are
    re-checked, so a stale artifact fails loudly instead of silently
    mapping a wrong partition."""
    return plan_from_dict(graph, record, validate=validate)


# ---------------------------------------------------------------------------
# pinned plan-override shipping (sweep workers, artifacts)
# ---------------------------------------------------------------------------

def override_records(systems, names=None) -> list[dict]:
    """Flatten the pinned per-workload plan overrides of a system registry
    (``SystemSpec.plan_overrides``) into JSON-able records — the wire
    format ``Experiment.sweep(workers=N)`` ships to spawn workers, whose
    fresh module-level registries would otherwise silently plan without
    the parent's pins.  ``names`` restricts to those systems (default:
    every registered system)."""
    recs: list[dict] = []
    for name in (systems.names() if names is None else names):
        spec = systems.get(name)
        for workload, sig in spec.plan_overrides:
            groups, tail_start = sig
            recs.append({"schema": OVERRIDE_SCHEMA, "system": name,
                         "workload": workload,
                         "groups": [list(g) for g in groups],
                         "tail_start": tail_start})
    return recs


def apply_override_records(systems, records: list[dict]) -> None:
    """Re-pin :func:`override_records` output onto a system registry
    (validating each signature against the system's tile grid, as
    ``SystemSpec.with_plan_override`` does).  Unknown schemas fail loudly
    — a silent skip would evaluate the wrong plan."""
    for rec in records:
        if rec.get("schema") != OVERRIDE_SCHEMA:
            raise ValueError(f"not a {OVERRIDE_SCHEMA} record "
                             f"(schema={rec.get('schema')!r})")
        spec = systems.get(rec["system"])
        sig = (tuple(tuple(g) for g in rec["groups"]),
               int(rec["tail_start"]))
        systems.register(rec["system"],
                         spec.with_plan_override(rec["workload"], sig),
                         replace=True)
